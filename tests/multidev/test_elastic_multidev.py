"""Elastic recovery on 8 fake devices: the plan-lowered reshard restore is
bit-identical to the host-mediated path, and an injected device loss
mid-training recovers in-process onto a *smaller* derived mesh with a
continuous loss curve (no replayed or skipped batches)."""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.configs.base import ModelConfig, get_strategy
from repro.core.compat import assert_close, make_jax_mesh, set_mesh
from repro.core.sharding import Mesh
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.launch.elastic import (
    ElasticCoordinator,
    FaultInjector,
    derive_mesh,
    sharding_problem,
    specs_by_key,
    state_partition_specs,
)
from repro.models import api
from repro.models.layers import tree_init
from repro.train import checkpoint as ckpt
from repro.train.loop import TrainConfig, TrainLoop
from repro.train.optimizer import get_optimizer

st = get_strategy("2d_finalized")
CFG = ModelConfig(
    name="t", family="dense", num_layers=2, d_model=32, num_heads=4,
    num_kv_heads=2, d_ff=64, vocab_size=64, attn_chunk=16, remat="none",
    qkv_bias=True,
)


def test_reshard_program_restore_bit_identical(tmp_path):
    """Save sharded on the full (2,4) mesh; restore onto a shrunk (2,2) mesh
    over the first 4 devices via the compiled reshard program — every leaf
    bit-identical to the host-mediated device_put restore."""
    jmesh = make_jax_mesh((2, 4), ("data", "model"))
    params = tree_init(api.param_tree(CFG, st), jax.random.PRNGKey(0))
    d = str(tmp_path / "ck")
    with set_mesh(jmesh):
        sharded = jax.jit(lambda p: p)(params)
        ckpt.save(d, 1, sharded)

    small_mesh, small_jmesh = derive_mesh(
        devices=jax.devices()[:4], model_parallel=2)
    assert small_mesh.shape == (2, 2)
    opt = get_optimizer("adafactor", lr=0.05)
    specs = specs_by_key(
        state_partition_specs(CFG, st, opt, TrainConfig()))
    pspecs = {k[len("params/"):]: v for k, v in specs.items()
              if k.startswith("params/")}
    restored, manifest, report = ckpt.restore_resharded(
        d, params, small_mesh, small_jmesh, target_specs=pspecs)
    assert report["step"] == 1 and report["leaves"] > 0

    with set_mesh(small_jmesh):
        host_mediated, _ = ckpt.restore(d, params)

    flat_a = jax.tree_util.tree_leaves(restored)
    flat_b = jax.tree_util.tree_leaves(host_mediated)
    flat_ref = jax.tree_util.tree_leaves(params)
    for a, b, r in zip(flat_a, flat_b, flat_ref):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(r))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # sharded restore I/O (per-shard byte-range reads) is bit-identical too,
    # and reads strictly less than leaves × full-size (shards share slices)
    shard_io, _, rep = ckpt.restore_resharded(
        d, params, small_mesh, small_jmesh, target_specs=pspecs,
        sharded_io=True)
    for a, r in zip(jax.tree_util.tree_leaves(shard_io), flat_ref):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(r))
    assert rep["sharded_io"] is True
    assert rep["io"]["unique_slices"] >= rep["io"]["leaves"]


def test_device_loss_recovers_on_smaller_mesh_in_process(tmp_path):
    """Lose 4 of 8 devices at step 5: the coordinator re-derives a (2,2)
    mesh, warm re-solves, reshard-restores, swaps the plan, and finishes —
    the loss curve has one loss per step and tracks the uninterrupted
    8-device run within partitioning tolerance."""
    from repro import autoshard

    steps = 10
    opt = get_optimizer("adafactor", lr=0.05)
    tc = TrainConfig(steps=steps, ckpt_dir=str(tmp_path / "ck"),
                     ckpt_every=2, keep_ckpts=3, log_every=1000)
    pipe = TokenPipeline(DataConfig(CFG.vocab_size, 16, 8, seed=7))
    inj = FaultInjector(device_loss_at=5, lose=4)
    co = ElasticCoordinator(
        CFG, st, opt, tc, pipe, model_parallel=2, injector=inj,
        autoshard_config=autoshard.AutoshardConfig(
            top_n=2, sa_steps=2, max_candidates=6),
        max_recoveries=2)
    assert co.mesh.shape == (4, 2)
    state, losses = co.run()
    assert len(losses) == steps
    assert len(co.recoveries) == 1
    ev = co.recoveries[0]
    assert ev["mesh"]["to"] == [2, 2]
    assert ev["warm_started"] and not ev["degraded"]
    assert ev["reshard"]["leaves"] > 0

    # uninterrupted reference on the original mesh
    tc_ref = TrainConfig(steps=steps, ckpt_dir=str(tmp_path / "ref"),
                         ckpt_every=2, keep_ckpts=3, log_every=1000)
    pipe_ref = TokenPipeline(DataConfig(CFG.vocab_size, 16, 8, seed=7))
    _, jmesh_full = derive_mesh(model_parallel=2)
    with set_mesh(jmesh_full):
        _, ref = TrainLoop(CFG, st, opt, tc_ref, pipe_ref,
                           rng=jax.random.PRNGKey(0)).run()
    assert_close(losses, ref, "loss_curve")


def test_fail_at_step_restart_on_smaller_mesh(tmp_path):
    """Process-restart flavor (satellite): TrainLoop with fail_at_step dies;
    a fresh loop on a smaller derived mesh resumes from the checkpoint data
    cursor — combined curve continues within tolerance, nothing replayed or
    skipped."""
    import pytest

    steps = 10
    opt = get_optimizer("adafactor", lr=0.05)
    d = str(tmp_path / "ck")
    pipe = TokenPipeline(DataConfig(CFG.vocab_size, 16, 8, seed=7))
    _, jmesh_full = derive_mesh(model_parallel=4)
    tc1 = TrainConfig(steps=steps, ckpt_dir=d, ckpt_every=2, keep_ckpts=3,
                      log_every=1000, fail_at_step=6)
    with set_mesh(jmesh_full):
        loop1 = TrainLoop(CFG, st, opt, tc1, pipe, rng=jax.random.PRNGKey(0))
        first = []
        loop1.hooks["metrics"] = lambda s, l: first.append((s, l))
        with pytest.raises(RuntimeError, match="injected failure"):
            loop1.run()

    # "restarted process": new loop, smaller mesh over 4 surviving devices
    _, jmesh_small = derive_mesh(devices=jax.devices()[:4], model_parallel=2)
    tc2 = TrainConfig(steps=steps, ckpt_dir=d, ckpt_every=2, keep_ckpts=3,
                      log_every=1000)
    pipe2 = TokenPipeline(DataConfig(CFG.vocab_size, 16, 8, seed=7))
    with set_mesh(jmesh_small):
        loop2 = TrainLoop(CFG, st, opt, tc2, pipe2,
                          rng=jax.random.PRNGKey(1))
        second = []
        loop2.hooks["metrics"] = lambda s, l: second.append((s, l))
        loop2.run()

    # resume point = data cursor of the last checkpoint (step 6), so the
    # combined per-step curve covers 0..steps-1 exactly once
    assert second[0][0] == 6
    combined = dict(first)
    combined.update(dict(second))
    assert sorted(combined) == list(range(steps))

    tc_ref = TrainConfig(steps=steps, ckpt_dir=str(tmp_path / "ref"),
                         ckpt_every=2, keep_ckpts=3, log_every=1000)
    pipe_ref = TokenPipeline(DataConfig(CFG.vocab_size, 16, 8, seed=7))
    with set_mesh(jmesh_full):
        _, ref = TrainLoop(CFG, st, opt, tc_ref, pipe_ref,
                           rng=jax.random.PRNGKey(0)).run()
    got = [combined[s] for s in range(steps)]
    assert_close(got, ref, "loss_curve")


def test_shrink_train_regrow_drill_continuous_curve(tmp_path):
    """Tentpole drill: 8 devices → lose 4 at step 4 (mesh (4,2)→(2,2)) →
    train → regain 4 at step 9 (regrow to (4,2)) → train to completion.
    Both re-solves warm-start (the regrow via expand_assignment), the regrow
    costs strictly fewer evals than a cold solve on the grown mesh, and the
    loss curve is continuous — one loss per step, tracking the uninterrupted
    8-device run within partitioning tolerance."""
    from repro import autoshard, obs

    obs.reset_control_events()
    steps = 14
    opt = get_optimizer("adafactor", lr=0.05)
    tc = TrainConfig(steps=steps, ckpt_dir=str(tmp_path / "ck"),
                     ckpt_every=2, keep_ckpts=3, log_every=1000)
    pipe = TokenPipeline(DataConfig(CFG.vocab_size, 16, 8, seed=7))
    inj = FaultInjector(schedule=[
        {"kind": "device_loss", "step": 4, "lose": 4},
        {"kind": "device_return", "step": 9, "gain": 4},
    ])
    cfgs = autoshard.AutoshardConfig(top_n=2, sa_steps=2, max_candidates=6)
    co = ElasticCoordinator(CFG, st, opt, tc, pipe, model_parallel=2,
                            injector=inj, autoshard_config=cfgs,
                            max_recoveries=3)
    assert co.mesh.shape == (4, 2)
    state, losses = co.run()
    assert co.mesh.shape == (4, 2)  # regrown back to the full world
    assert len(losses) == steps     # continuous: one loss per step
    shrink, regrow = co.recoveries
    assert shrink["classes"] == ["device_loss"]
    assert shrink["mesh"] == {"from": [4, 2], "to": [2, 2]}
    assert regrow["classes"] == ["device_return"]
    assert regrow["mesh"] == {"from": [2, 2], "to": [4, 2]}
    assert shrink["warm_started"] and regrow["warm_started"]
    assert regrow["reshard"]["leaves"] > 0

    # the regrow warm start beats a cold solve on the grown mesh
    closed, baseline = sharding_problem(CFG, st, co.mesh,
                                        pipe.local_batch, 16)
    cold = autoshard.solve_problem(closed, co.mesh, cfgs, baseline=baseline)
    assert regrow["evals"] < cold.evals

    names = [e["name"] for e in obs.control_events()]
    assert "mesh_shrink" in names and "mesh_grow" in names
    assert names.count("restore") == 2

    # uninterrupted 8-device reference
    tc_ref = TrainConfig(steps=steps, ckpt_dir=str(tmp_path / "ref"),
                         ckpt_every=2, keep_ckpts=3, log_every=1000)
    pipe_ref = TokenPipeline(DataConfig(CFG.vocab_size, 16, 8, seed=7))
    _, jmesh_full = derive_mesh(model_parallel=2)
    with set_mesh(jmesh_full):
        _, ref = TrainLoop(CFG, st, opt, tc_ref, pipe_ref,
                           rng=jax.random.PRNGKey(0)).run()
    assert_close(losses, ref, "loss_curve")


def test_combined_nan_and_device_loss_single_pass_multidev(tmp_path):
    """Coincident NaN burst + device loss on the real 8-device mesh: one
    classification, one mesh shrink, exactly one reshard-restore."""
    from repro import autoshard, obs
    from repro.core.plan import GuardConfig

    obs.reset_control_events()
    steps = 12
    opt = get_optimizer("adafactor", lr=0.05)
    tc = TrainConfig(steps=steps, ckpt_dir=str(tmp_path / "ck"),
                     ckpt_every=2, keep_ckpts=3, log_every=1000,
                     guard=GuardConfig(rewind_after=2))
    pipe = TokenPipeline(DataConfig(CFG.vocab_size, 16, 8, seed=7))
    inj = FaultInjector(nan_at_step=5, numeric_steps=2,
                        device_loss_at=6, lose=4)
    co = ElasticCoordinator(
        CFG, st, opt, tc, pipe, model_parallel=2, injector=inj,
        autoshard_config=autoshard.AutoshardConfig(
            top_n=2, sa_steps=2, max_candidates=6),
        max_recoveries=2)
    state, losses = co.run()
    assert len(co.recoveries) == 1
    ev = co.recoveries[0]
    assert ev["classes"] == ["device_loss", "numerics"]
    assert ev["mesh"] == {"from": [4, 2], "to": [2, 2]}
    assert "restored_from" in ev
    events = obs.control_events()
    names = [e["name"] for e in events]
    assert names.count("restore") == 1
    assert names.count("combined_recovery") == 1
    narr = obs.recovery_narrative(events)
    assert len(narr) == 1 and narr[0]["restores"] == 1
