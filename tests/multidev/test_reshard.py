"""Direct reshard execution tests on 8 fake devices (via the launcher).

The planner's *decisions* are unit-tested in tests/test_plan.py; here every
planned program is executed inside a real shard_map region and checked for the
GSPMD identity guarantee: resharding never changes the global tensor.
"""
import itertools
import os
import sys

import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core import Mesh, annotate, mesh_split
from repro.core.compat import assert_close, make_jax_mesh, shard_map
from repro.core.collective_planner import plan_reshard
from repro.core.einsum_rules import partitioned_einsum
from repro.core.reshard import reshard_local
from repro.core.sharding import to_partition_spec

jmesh = make_jax_mesh((2, 4), ("x", "y"))
mesh = Mesh.create((2, 4), ("x", "y"))
rng = np.random.default_rng(0)


def roundtrip(x, src, dst):
    """Shard x as src, reshard to dst inside shard_map, return the global view."""
    f = shard_map(
        lambda xl: reshard_local(xl, src, dst),
        mesh=jmesh,
        in_specs=to_partition_spec(src),
        out_specs=to_partition_spec(dst),
    )
    return np.asarray(f(x))


def test_alltoall_dim_move_identity():
    x = rng.standard_normal((8, 16)).astype(np.float32)
    src = mesh_split(2, mesh, ["y", -1])
    dst = mesh_split(2, mesh, [-1, "y"])
    prog = plan_reshard(src, dst, (2, 16), 4)
    assert [s.op for s in prog.steps] == ["all_to_all"]
    np.testing.assert_array_equal(roundtrip(x, src, dst), x)


def test_slice_before_gather_identity():
    x = rng.standard_normal((8, 16)).astype(np.float32)
    src = mesh_split(2, mesh, ["x", -1])
    dst = mesh_split(2, mesh, [-1, "y"])
    prog = plan_reshard(src, dst, (4, 16), 4)
    assert [s.op for s in prog.steps] == ["dynamic_slice", "all_gather"]
    np.testing.assert_array_equal(roundtrip(x, src, dst), x)


def test_stacked_axes_gather_ordering_identity():
    """d0=(x,y): dropping both must gather the inner axis first; the data must
    come back in original order (the ordering is what tiled gather encodes)."""
    x = rng.standard_normal((8, 8)).astype(np.float32)
    src = mesh_split(2, mesh, [("x", "y"), -1])
    for dst_spec in ([-1, -1], ["x", -1], ["x", "y"]):
        dst = mesh_split(2, mesh, dst_spec)
        np.testing.assert_array_equal(roundtrip(x, src, dst), x)


def test_exhaustive_pairs_identity():
    """Every reachable (src, dst) pair over a rank-2 tensor is an identity."""
    opts = [(), ("x",), ("y",), ("x", "y"), ("y", "x")]
    shardings = [
        mesh_split(2, mesh, [d0 or -1, d1 or -1])
        for d0, d1 in itertools.product(opts, opts)
        if not (set(d0) & set(d1))
    ]
    x = rng.standard_normal((8, 8)).astype(np.float32)
    for src, dst in itertools.product(shardings, shardings):
        got = roundtrip(x, src, dst)
        np.testing.assert_array_equal(got, x, err_msg=f"{src} -> {dst}")


def test_partitioned_einsum_reduce_scatter_path():
    """Contracting-matched einsum with an output that wants the psum axis:
    must run as local-einsum + psum_scatter and match the oracle."""
    x = rng.standard_normal((8, 8)).astype(np.float32)
    w = rng.standard_normal((8, 8)).astype(np.float32)
    lhs_sh = mesh_split(2, mesh, [-1, "y"])
    rhs_sh = mesh_split(2, mesh, ["y", -1])
    out_sh = mesh_split(2, mesh, ["y", -1])

    from repro.core.einsum_rules import compile_einsum

    plan = compile_einsum("bd,df->bf", lhs_sh, rhs_sh, out_sh, (8, 2), (2, 8))
    assert plan.scatter == (("y", 0),) and plan.reduce_axes == ()

    def local(xl, wl):
        z, sh = partitioned_einsum("bd,df->bf", xl, wl, lhs_sh, rhs_sh, out_sh)
        assert sh.dims_mapping == out_sh.dims_mapping
        return z

    f = shard_map(
        local, mesh=jmesh,
        in_specs=(to_partition_spec(lhs_sh), to_partition_spec(rhs_sh)),
        out_specs=to_partition_spec(out_sh),
    )
    assert_close(f(x, w), x @ w, "f32_chain")


def test_fallback_concatenate_keeps_batch_sharding():
    """The partial fallback runs concatenate locally on the kept (sharded)
    batch dim — and stays numerically exact."""
    from repro.core.partitioner import spmd_partition

    def f(a, b):
        a = annotate(a, mesh_split(2, mesh, ["y", -1]))
        b = annotate(b, mesh_split(2, mesh, ["y", -1]))
        return jnp.concatenate([a, b], axis=1) * 2.0

    a = rng.standard_normal((8, 4)).astype(np.float32)
    b = rng.standard_normal((8, 6)).astype(np.float32)
    got = spmd_partition(f, jmesh, mesh)(a, b)
    assert_close(got, np.concatenate([a, b], axis=1) * 2.0, "f32")
