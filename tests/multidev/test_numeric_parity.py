"""GSPMD's core guarantee: partitioned (multi-device) == single-device numerics,
for real model training steps across strategies; elastic checkpoint restore."""
import os

import jax

from repro.core.compat import assert_close, make_jax_mesh, set_mesh
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, get_strategy
from repro.models import api
from repro.models.layers import tree_init
from repro.train import checkpoint as ckpt

jmesh = make_jax_mesh((2, 4), ("data", "model"))

CFG = ModelConfig(
    name="t", family="dense", num_layers=2, d_model=32, num_heads=4,
    num_kv_heads=2, d_ff=64, vocab_size=64, attn_chunk=16, remat="none",
    qkv_bias=True,
)


@pytest.mark.parametrize("strategy", ["2d_attempt1", "2d_attempt2", "2d_finalized"])
def test_sharded_loss_matches_unsharded(strategy):
    st = get_strategy(strategy)
    rng = jax.random.PRNGKey(0)
    tok = jax.random.randint(rng, (8, 16), 0, CFG.vocab_size, jnp.int32)
    batch = {"tokens": tok, "labels": tok}

    # single-device oracle (no mesh context -> constraints are no-ops)
    params = tree_init(api.param_tree(CFG, st), rng)
    loss_ref = float(api.loss_fn(CFG, st, params, batch))

    with set_mesh(jmesh):
        params_s = jax.tree_util.tree_map(jnp.asarray, params)
        loss_sharded = float(
            jax.jit(lambda p, b: api.loss_fn(CFG, st, p, b))(params_s, batch)
        )
    assert abs(loss_sharded - loss_ref) < 5e-2, (loss_sharded, loss_ref)


def test_sharded_gqa_padded_heads_match():
    """kv=2 heads on a 4-wide model axis exercises the replica/padded layout."""
    st = get_strategy("2d_finalized")
    rng = jax.random.PRNGKey(1)
    cfg = CFG.with_(num_heads=6, num_kv_heads=2, head_dim=8)  # G=3, r=2 -> Gp=4
    params = tree_init(api.param_tree(cfg, st), rng)
    tok = jax.random.randint(rng, (8, 16), 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": tok, "labels": tok}
    loss_ref = float(api.loss_fn(cfg, st, params, batch))
    with set_mesh(jmesh):
        loss_sharded = float(
            jax.jit(lambda p, b: api.loss_fn(cfg, st, p, b))(params, batch)
        )
    assert abs(loss_sharded - loss_ref) < 5e-2


def test_moe_sharded_parity():
    st = get_strategy("moe_2d")
    cfg = CFG.with_(moe=True, num_experts=4, top_k=2, moe_every=1,
                    capacity_factor=4.0)  # high capacity: no dropped tokens
    rng = jax.random.PRNGKey(2)
    params = tree_init(api.param_tree(cfg, st), rng)
    tok = jax.random.randint(rng, (8, 16), 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": tok, "labels": tok}
    loss_ref = float(api.loss_fn(cfg, st, params, batch))
    with set_mesh(jmesh):
        loss_sharded = float(
            jax.jit(lambda p, b: api.loss_fn(cfg, st, p, b))(params, batch)
        )
    assert abs(loss_sharded - loss_ref) < 5e-2


def test_elastic_restore_across_meshes(tmp_path):
    """Save sharded on (2,4); restore onto (4,2) and (8,1) — values identical
    (the elastic-scaling path: mesh changes, checkpoint doesn't)."""
    st = get_strategy("2d_finalized")
    params = tree_init(api.param_tree(CFG, st), jax.random.PRNGKey(0))
    d = str(tmp_path / "ck")
    with set_mesh(jmesh):
        sharded = jax.jit(lambda p: p)(params)
        ckpt.save(d, 1, sharded)
    flat_ref = jax.tree_util.tree_leaves(params)
    for shape in [(4, 2), (8, 1)]:
        m2 = make_jax_mesh(shape, ("data", "model"))
        with set_mesh(m2):
            restored, _ = ckpt.restore(d, params)
            flat_new = jax.tree_util.tree_leaves(restored)
            for a, b in zip(flat_ref, flat_new):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_manual_mode_subgroups():
    """§3.4: manual subgraph on one mesh axis, automatic on the other."""
    from repro.core.manual import manual

    def local_fn(x):
        # manual on "model": x arrives model-sharded, we psum manually
        return jax.lax.psum(x, "model")

    f = manual(local_fn, jmesh, in_specs=P(None, "model"), out_specs=P(None))
    x = np.arange(32.0, dtype=np.float32).reshape(4, 8)
    got = np.asarray(f(x))
    # model axis = 4 shards of size 2 along dim 1; psum sums the shards
    ref = x.reshape(4, 4, 2).sum(axis=1)
    assert_close(got, ref, "f32_dot")
