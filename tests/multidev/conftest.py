import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

# These tests need 8 fake devices (XLA_FLAGS set before jax init); when not
# launched through test_multidev_launcher.py, collect nothing.
if os.environ.get("REPRO_MULTIDEV") != "1":
    collect_ignore_glob = ["*"]
