"""Elastic coordinator: in-process fault recovery on a single device, plus
pure-planning warm-vs-cold autoshard comparisons on multi-device mesh shapes
(no devices needed for cost-only solves).  The real 8-device mesh-shrink
recovery runs in tests/multidev/test_elastic_multidev.py."""
import dataclasses
import os

import jax
import numpy as np
import pytest

from repro import autoshard
from repro.configs.base import ModelConfig, get_strategy
from repro.core.sharding import Mesh
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.launch.elastic import (
    DeviceLossError,
    ElasticCoordinator,
    FaultInjector,
    derive_mesh,
    sharding_problem,
    specs_by_key,
    state_partition_specs,
)
from repro.train.loop import TrainConfig, TrainLoop
from repro.train.optimizer import get_optimizer

st = get_strategy("2d_finalized")
TINY = ModelConfig(
    name="tiny", family="dense", num_layers=2, d_model=32, num_heads=4,
    num_kv_heads=4, d_ff=64, vocab_size=128, attn_chunk=16, remat="none",
)


CHEAP = autoshard.AutoshardConfig(top_n=2, sa_steps=2, max_candidates=6)


def make_coordinator(tmp_path, steps=10, injector=None, **kw):
    opt = get_optimizer("adafactor", lr=0.05)
    tc = TrainConfig(steps=steps, ckpt_dir=str(tmp_path / "ck"), ckpt_every=2,
                     keep_ckpts=3, log_every=1000)
    pipe = TokenPipeline(DataConfig(TINY.vocab_size, 16, 4, seed=7))
    kw.setdefault("autoshard_config", CHEAP)
    return ElasticCoordinator(TINY, st, opt, tc, pipe, injector=injector, **kw)


def test_derive_mesh_shapes_and_clamp():
    mesh, jmesh = derive_mesh(n_devices=1)
    assert mesh.shape == (1, 1) and tuple(jmesh.devices.shape) == (1, 1)
    assert mesh.axis_names == ("data", "model")
    # model_parallel larger than the world clamps to a divisor
    mesh, _ = derive_mesh(n_devices=1, model_parallel=4)
    assert mesh.shape == (1, 1)


def test_device_loss_recovery_matches_uninterrupted_run(tmp_path):
    """Fault at step 5 → restore from the last checkpoint, warm re-solve,
    plan swap, resume: the returned loss curve is one loss per step and
    bitwise-matches an uninterrupted run (same seeds, same batches — nothing
    replayed into the curve, nothing skipped)."""
    inj = FaultInjector(device_loss_at=5, lose=0)  # 1-device world: lose none
    co = make_coordinator(tmp_path, steps=10, injector=inj, max_recoveries=2)
    state, losses = co.run()
    assert len(losses) == 10
    assert len(co.recoveries) == 1
    ev = co.recoveries[0]
    assert ev["warm_started"] and not ev["degraded"]
    assert ev["reshard"]["leaves"] > 0

    # uninterrupted reference
    opt = get_optimizer("adafactor", lr=0.05)
    tc = TrainConfig(steps=10, ckpt_dir=str(tmp_path / "ref"), ckpt_every=2,
                     keep_ckpts=3, log_every=1000)
    pipe = TokenPipeline(DataConfig(TINY.vocab_size, 16, 4, seed=7))
    _, ref = TrainLoop(TINY, st, opt, tc, pipe,
                       rng=jax.random.PRNGKey(0)).run()
    np.testing.assert_allclose(losses, ref, rtol=1e-6)


def test_exhausted_recoveries_reraise(tmp_path):
    inj = FaultInjector(device_loss_at=5, lose=0)
    co = make_coordinator(tmp_path, steps=10, injector=inj, max_recoveries=0)
    with pytest.raises(DeviceLossError):
        co.run()


def test_crash_mid_save_resumes_from_intact_step(tmp_path):
    inj = FaultInjector(crash_save_at_leaf=3)
    co = make_coordinator(tmp_path, steps=8, injector=inj, max_recoveries=2)
    state, losses = co.run()
    assert len(losses) == 8
    assert any(r.get("crash_save") for r in co.recoveries)
    # the final checkpoint committed; no orphan tmp dirs break latest_step
    from repro.train import checkpoint as ckpt
    assert ckpt.latest_step(str(tmp_path / "ck")) == 8


def test_straggler_stall_trips_watchdog(tmp_path):
    events = []
    inj = FaultInjector(straggler_at=9, stall_s=0.3)
    co = make_coordinator(
        tmp_path, steps=12, injector=inj,
        hooks={"straggler": lambda step, dt, med: events.append(step)})
    co.tc.straggler_factor = 2.0
    co.loop.tc.straggler_factor = 2.0
    co.run()
    assert 9 in events


def test_warm_start_fewer_evals_than_cold():
    """Automap-style warm start across a mesh shrink: strictly fewer cost
    lowerings, no worse score (pure planning, no devices)."""
    cfgs = CHEAP
    old = Mesh.create((2, 4), ("data", "model"))
    closed, baseline = sharding_problem(TINY, st, old, 4, 16)
    prior = autoshard.solve_problem(closed, old, cfgs, baseline=baseline)
    assert not prior.warm_started

    new = Mesh.create((2, 2), ("data", "model"))
    closed2, baseline2 = sharding_problem(TINY, st, new, 4, 16)
    shapes = [tuple(v.aval.shape) for v in closed2.jaxpr.invars]
    warm = autoshard.remap_assignment(prior.assignment, new, shapes)
    warm_res = autoshard.solve_problem(closed2, new, cfgs, baseline=baseline2,
                                       warm_start=warm)
    cold_res = autoshard.solve_problem(closed2, new, cfgs, baseline=baseline2)
    assert warm_res.warm_started
    assert warm_res.evals < cold_res.evals
    assert warm_res.evaluation.score <= cold_res.evaluation.score * (1 + 1e-6)


def test_warm_start_roundtrips_through_json_dump(tmp_path):
    cfgs = CHEAP
    old = Mesh.create((2, 4), ("data", "model"))
    closed, baseline = sharding_problem(TINY, st, old, 4, 16)
    prior = autoshard.solve_problem(closed, old, cfgs, baseline=baseline)
    p = str(tmp_path / "assignment.json")
    prior.dump(p)
    _, loaded = autoshard.load(p)
    new = Mesh.create((2, 2), ("data", "model"))
    closed2, baseline2 = sharding_problem(TINY, st, new, 4, 16)
    shapes = [tuple(v.aval.shape) for v in closed2.jaxpr.invars]
    warm = autoshard.remap_assignment(loaded, new, shapes)
    res = autoshard.solve_problem(closed2, new, cfgs, baseline=baseline2,
                                  warm_start=warm)
    assert res.warm_started and res.to_json()["warm_started"]


def test_infeasible_budget_degrades_to_data_parallel(tmp_path):
    """A budget no assignment can satisfy must not abort: the coordinator
    falls back to the data-parallel-only restriction of the baseline."""
    co = make_coordinator(
        tmp_path, steps=2,
        autoshard_config=autoshard.AutoshardConfig(
            top_n=2, sa_steps=2, budget_bytes=1.0))
    res = co.solve_assignment()
    assert co.degraded
    for s in res.assignment:
        if s is None:
            continue
        axes = {a for dim in s.dims_mapping for a in dim}
        assert axes <= {"data"}, s
    assert os.path.exists(co.dump_path)


def test_state_partition_specs_cover_state(tmp_path):
    from repro.train.loop import init_state

    opt = get_optimizer("adafactor", lr=0.05)
    tc = TrainConfig(steps=1)
    state = init_state(TINY, st, opt, tc, jax.random.PRNGKey(0))
    from repro.train.checkpoint import _flatten_with_paths

    keys = {k for k, _ in _flatten_with_paths(state)[0]}
    specs = specs_by_key(state_partition_specs(TINY, st, opt, tc))
    assert keys == set(specs)


def test_recovery_story_reconstructable_from_trace(tmp_path):
    """Satellite drill: run a FaultInjector-driven rewind and reconstruct the
    whole fault → skip → rewind → plan-swap story purely from the exported
    control-lane trace events — no coordinator state consulted."""
    from repro import obs
    from repro.core.plan import GuardConfig

    obs.reset_control_events()
    opt = get_optimizer("adafactor", lr=0.05)
    tc = TrainConfig(steps=12, ckpt_dir=str(tmp_path / "ck"), ckpt_every=3,
                     guard=GuardConfig(rewind_after=2), log_every=1000)
    pipe = TokenPipeline(DataConfig(TINY.vocab_size, 16, 4, seed=7))
    inj = FaultInjector(nan_at_step=5, numeric_steps=4)
    co = ElasticCoordinator(TINY, st, opt, tc, pipe, n_devices=1,
                            injector=inj, max_recoveries=2,
                            autoshard_config=CHEAP)
    _, losses = co.run()
    assert len(losses) == 11  # one skipped batch, training completed

    doc = obs.export_control_trace()
    assert obs.validate_trace_events(doc["traceEvents"]) == []
    instants = sorted(
        (e for e in doc["traceEvents"] if e["ph"] == "i"),
        key=lambda e: e["ts"])
    names = [e["name"] for e in instants]
    # the full recovery story, in causal order, from the trace alone
    first_fault = names.index("numerics_fault")
    skip = names.index("skip_step")
    rewind = names.index("rewind")
    swap = names.index("plan_swap")
    assert first_fault < skip < rewind < swap
    # two consecutive faults tripped the rewind threshold
    faults = [e for e in instants if e["name"] == "numerics_fault"]
    assert faults[-1]["args"]["consecutive"] == 2
    assert [e["args"]["step"] for e in faults[:2]] == [5, 6]
    # the skip names the dropped batch, the swap says why it happened
    (skip_ev,) = [e for e in instants if e["name"] == "skip_step"]
    assert skip_ev["args"]["step"] == 5
    swap_ev = instants[swap]
    assert swap_ev["args"]["reason"] == "rewind"
    # counters landed in the unified registry alongside the trace
    snap = obs.snapshot()
    assert snap["counters"]["train.guard.faults"] >= 2
    assert snap["counters"]["train.guard.rewinds"] >= 1


def test_expand_assignment_regrow_warm_fewer_evals():
    """Regrow counterpart of the shrink warm start: a DP-only (2,1)
    assignment (the post-shrink / degraded shape — model axis collapsed)
    lifts onto (2,4) via expand_assignment, which re-proposes the freed
    model axis instead of merely name-projecting (remap would leave every
    leaf DP-only forever), and the warm solve costs strictly fewer evals."""
    small = Mesh.create((2, 1), ("data", "model"))
    closed_s, base_s = sharding_problem(TINY, st, small, 4, 16)
    shapes_s = [tuple(v.aval.shape) for v in closed_s.jaxpr.invars]
    # the DP-only restriction is exactly what a degraded coordinator dumps
    prior = autoshard.restrict_assignment(base_s, small, shapes_s)

    big = Mesh.create((2, 4), ("data", "model"))
    closed_b, base_b = sharding_problem(TINY, st, big, 4, 16)
    shapes = [tuple(v.aval.shape) for v in closed_b.jaxpr.invars]
    warm = autoshard.expand_assignment(prior, big, shapes)
    remap = autoshard.remap_assignment(prior, big, shapes)
    dms = lambda a: [None if s is None else s.dims_mapping for s in a]
    assert dms(warm) != dms(remap)  # the lift re-proposed freed capacity
    warm_res = autoshard.solve_problem(closed_b, big, CHEAP, baseline=base_b,
                                       warm_start=warm)
    cold_res = autoshard.solve_problem(closed_b, big, CHEAP, baseline=base_b)
    assert warm_res.warm_started
    assert warm_res.evals < cold_res.evals


def test_schedule_json_round_trip_and_validation(tmp_path):
    sched = [{"kind": "device_loss", "step": 3, "lose": 0},
             {"kind": "nan_burst", "step": 7, "steps": 1}]
    inj = FaultInjector(schedule=sched)
    p = str(tmp_path / "campaign.json")
    doc = inj.dump_schedule(p)
    assert doc["version"] == 1
    assert FaultInjector.load_schedule(p).schedule == sched
    assert FaultInjector.load_schedule(doc).schedule == sched
    assert FaultInjector.load_schedule(sched).schedule == sched
    with pytest.raises(ValueError, match="unknown schedule"):
        FaultInjector(schedule=[{"kind": "meteor", "step": 1}])
    with pytest.raises(ValueError, match="missing step"):
        FaultInjector(schedule=[{"kind": "nan_burst"}])


def test_shrink_then_regrow_drill_continuous_curve(tmp_path):
    """Tentpole drill (1-device edition; the 8-device mesh-shape version is
    in tests/multidev): schedule-driven shrink → train → regrow → train,
    both recoveries warm-started, one restore each, continuous loss curve,
    and the whole campaign reconstructable from the exported trace alone."""
    from repro import obs

    obs.reset_control_events()
    sched = [{"kind": "device_loss", "step": 3, "lose": 0},
             {"kind": "device_return", "step": 7, "gain": 0}]
    inj = FaultInjector(schedule=sched)
    co = make_coordinator(tmp_path, steps=12, injector=inj, max_recoveries=3)
    state, losses = co.run()
    assert len(losses) == 12  # one loss per step, continuous across both
    assert [r["classes"] for r in co.recoveries] == [
        ["device_loss"], ["device_return"]]
    assert all(r["warm_started"] and not r["degraded"]
               for r in co.recoveries)
    events = obs.control_events()
    names = [e["name"] for e in events]
    assert "mesh_shrink" in names and "mesh_grow" in names
    assert names.count("restore") == 2  # one restore pass per recovery
    # injections are distinguishable from the reactions they caused
    chaos = [e["args"]["kind"] for e in events if e["name"] == "chaos_event"]
    assert chaos == ["device_loss", "device_return"]
    # the campaign narrative rebuilds from the trace alone
    narr = obs.recovery_narrative(events)
    assert [ep["classes"] for ep in narr] == [
        ["device_loss"], ["device_return"]]
    assert all(ep["restores"] == 1 for ep in narr)


def test_combined_nan_and_device_loss_single_restore(tmp_path):
    """Coincident NumericsFault + device loss resolve in ONE recovery pass:
    one classification, one mesh change, one restore_resharded — asserted
    from the control lane, and the provenance lands in the manifest."""
    from repro import obs
    from repro.core.plan import GuardConfig
    from repro.train import checkpoint as ckpt

    obs.reset_control_events()
    opt = get_optimizer("adafactor", lr=0.05)
    tc = TrainConfig(steps=12, ckpt_dir=str(tmp_path / "ck"), ckpt_every=2,
                     guard=GuardConfig(rewind_after=2), log_every=1000)
    pipe = TokenPipeline(DataConfig(TINY.vocab_size, 16, 4, seed=7))
    inj = FaultInjector(nan_at_step=5, numeric_steps=2,
                        device_loss_at=6, lose=0)
    co = ElasticCoordinator(TINY, st, opt, tc, pipe, n_devices=1,
                            injector=inj, max_recoveries=2,
                            autoshard_config=CHEAP)
    _, losses = co.run()
    assert len(co.recoveries) == 1
    ev = co.recoveries[0]
    assert ev["classes"] == ["device_loss", "numerics"]
    assert "restored_from" in ev and ev["reshard"]["leaves"] > 0
    events = obs.control_events()
    names = [e["name"] for e in events]
    assert names.count("restore") == 1        # exactly one restore pass
    assert names.count("combined_recovery") == 1
    (comb,) = [e for e in events if e["name"] == "combined_recovery"]
    assert comb["args"]["classes"] == ["device_loss", "numerics"]
    # the narrative sees one episode covering both classes
    narr = obs.recovery_narrative(events)
    assert len(narr) == 1 and narr[0]["restores"] == 1
    assert narr[0]["classes"] == ["device_loss", "numerics"]
    # provenance reached the next manifest's extra
    d = str(tmp_path / "ck")
    man = ckpt._load_manifest(d, ckpt.latest_step(d))
    rec = man["extra"]["recovery"]
    assert rec["count"] == 1
    assert rec["last"]["classes"] == ["device_loss", "numerics"]
