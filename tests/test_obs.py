"""Observability layer: metrics registry, plan-step tracing, calibration.

Covers the PR-8 acceptance surface: thread-safe instruments and JSON
snapshot round-trips, the Chrome trace-event schema validator (including
seeded-invalid events and lane-overlap detection), modeled-timeline /
overlap-schedule consistency, measured tracing on a real (1-device) runner
with numerics unchanged vs the untraced path, per-class calibration joins,
control-event export, and the ``python -m repro.obs`` CLI.
"""
import json
import os
import sys
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(__file__))

from repro import obs
from repro.core import Mesh, annotate, mesh_split, propagate
# imported for their snapshot sources (joined lazily via sys.modules)
from repro.core import partitioner as _partitioner  # noqa: F401
from repro.core import plan_verify as _plan_verify  # noqa: F401
from repro.core.plan import compile_plan
from repro.core.plan_opt import modeled_timeline, step_class
from repro.obs import calibrate, metrics, trace

mesh = Mesh.create((4, 8), ("x", "y"))


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _plan(f, *avals):
    closed = jax.make_jaxpr(f)(*avals)
    prop = propagate(closed, mesh).result()
    return compile_plan(closed, prop, mesh)


def _mlp(a, w1, w2):
    a = annotate(a, mesh_split(2, mesh, ["x", -1]))
    w1 = annotate(w1, mesh_split(2, mesh, [-1, "y"]))
    h = jnp.maximum(a @ w1, 0.0)
    h = annotate(h, mesh_split(2, mesh, ["x", -1]))
    return h @ w2


MLP_AVALS = (_f32(64, 32), _f32(32, 64), _f32(64, 16))


# ---------------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------------


def test_counter_thread_safety():
    reg = metrics.MetricsRegistry()
    n_threads, per_thread = 8, 2000

    def work():
        for _ in range(per_thread):
            reg.inc("hits")

    threads = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert reg.counter("hits").value == n_threads * per_thread


def test_histogram_concurrent_observe_keeps_count_and_sum():
    reg = metrics.MetricsRegistry()
    n_threads, per_thread = 4, 500

    def work():
        for i in range(per_thread):
            reg.observe("lat", float(i))

    threads = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    h = reg.histogram("lat")
    assert h.count == n_threads * per_thread
    assert h.summary()["sum"] == pytest.approx(
        n_threads * sum(range(per_thread)))


def test_histogram_percentiles_match_numpy():
    h = metrics.Histogram("h")
    rng = np.random.RandomState(0)
    vals = rng.exponential(10.0, size=501)
    for v in vals:
        h.observe(float(v))
    for p in (0, 25, 50, 90, 99, 100):
        assert h.percentile(p) == pytest.approx(np.percentile(vals, p))
    s = h.summary()
    assert s["count"] == 501
    assert s["min"] == pytest.approx(vals.min())
    assert s["max"] == pytest.approx(vals.max())
    assert s["mean"] == pytest.approx(vals.mean())


def test_histogram_thinning_keeps_exact_count():
    h = metrics.Histogram("h")
    n = metrics.MAX_SAMPLES + 1000
    for i in range(n):
        h.observe(float(i))
    assert h.count == n                       # count/sum/min/max stay exact
    assert h.summary()["max"] == float(n - 1)
    assert len(h._values) <= metrics.MAX_SAMPLES
    # percentiles stay representative after 2:1 thinning (post-thin samples
    # arrive unthinned, so recent values are slightly over-weighted)
    assert h.percentile(50) == pytest.approx((n - 1) / 2, rel=0.05)


def test_empty_and_single_sample_percentiles():
    h = metrics.Histogram("h")
    assert h.percentile(50) is None
    assert h.summary()["mean"] is None
    h.observe(7.0)
    assert h.percentile(0) == h.percentile(100) == 7.0


def test_snapshot_roundtrips_through_json(tmp_path):
    reg = metrics.MetricsRegistry()
    reg.inc("a.hits", 3)
    reg.set_gauge("mesh.devices", 8)
    for v in (1.0, 2.0, 3.0):
        reg.observe("step_ms", v)
    p = reg.dump(str(tmp_path / "m.json"))
    with open(p) as f:
        snap = json.load(f)
    assert snap["counters"]["a.hits"] == 3
    assert snap["gauges"]["mesh.devices"] == 8
    assert snap["histograms"]["step_ms"]["count"] == 3
    assert snap["histograms"]["step_ms"]["p50"] == 2.0
    # builtin sources joined (core modules are imported by this test session)
    assert "lattice" in snap["sources"]
    assert "plan_verify" in snap["sources"]
    assert "process_plan_cache" in snap["sources"]


def test_broken_source_degrades_to_error_marker():
    reg = metrics.MetricsRegistry()

    def boom():
        raise RuntimeError("source down")

    reg.register_source("flaky", boom)
    snap = reg.snapshot()
    assert snap["sources"]["flaky"] == {"error": "source down"}


def test_reset_clears_instruments_keeps_sources():
    reg = metrics.MetricsRegistry()
    reg.inc("x")
    reg.register_source("s", lambda: {"ok": 1})
    reg.reset()
    snap = reg.snapshot()
    assert snap["counters"] == {}
    assert snap["sources"]["s"] == {"ok": 1}


def test_maybe_dump_env(tmp_path, monkeypatch):
    p = str(tmp_path / "dump.json")
    monkeypatch.setenv(metrics.DUMP_ENV, p)
    metrics.inc("dump.test.marker")
    assert metrics.maybe_dump() == p
    with open(p) as f:
        assert json.load(f)["counters"]["dump.test.marker"] >= 1
    monkeypatch.delenv(metrics.DUMP_ENV)
    assert metrics.maybe_dump() is None


def test_atexit_dump_writes_snapshot_in_subprocess(tmp_path):
    """The ``REPRO_METRICS_DUMP`` atexit hook (registered at import when the
    env var is set) must write a loadable snapshot when the interpreter
    exits normally — the in-process ``maybe_dump`` test above can't cover
    the atexit path itself."""
    import subprocess

    p = str(tmp_path / "atexit.json")
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env = dict(os.environ)
    env[metrics.DUMP_ENV] = p
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + \
        env.get("PYTHONPATH", "")
    code = (
        "from repro.obs import metrics\n"
        "metrics.inc('atexit.test.marker', 2)\n"
        "metrics.set_gauge('atexit.test.gauge', 1.5)\n"
        "metrics.observe('atexit.test.hist', 3.0)\n"
    )
    subprocess.run([sys.executable, "-c", code], check=True, env=env,
                   timeout=120)
    with open(p) as f:
        snap = json.load(f)
    assert snap["counters"]["atexit.test.marker"] == 2
    assert snap["gauges"]["atexit.test.gauge"] == 1.5
    assert snap["histograms"]["atexit.test.hist"]["count"] == 1


def test_module_level_registry_is_process_wide():
    metrics.inc("proc.wide.marker", 5)
    assert metrics.registry().counter("proc.wide.marker").value >= 5
    assert metrics.snapshot()["counters"]["proc.wide.marker"] >= 5


# ---------------------------------------------------------------------------------
# trace schema validator
# ---------------------------------------------------------------------------------


def _span(name, ts, dur, pid=2, tid=1, **args):
    return {"name": name, "ph": "X", "ts": ts, "dur": dur, "pid": pid,
            "tid": tid, "args": args}


def test_validator_accepts_valid_events():
    events = [
        {"name": "process_name", "ph": "M", "pid": 1, "args": {"name": "m"}},
        _span("a", 0.0, 10.0),
        _span("b", 10.0, 5.0),
        {"name": "fault", "ph": "i", "s": "g", "ts": 3.0, "pid": 3, "tid": 1},
    ]
    assert trace.validate_trace_events(events) == []


def test_validator_allows_proper_nesting():
    events = [_span("outer", 0.0, 100.0), _span("inner", 10.0, 20.0),
              _span("inner2", 40.0, 50.0)]
    assert trace.validate_trace_events(events) == []


def test_validator_flags_partial_overlap_within_lane():
    events = [_span("a", 0.0, 10.0), _span("b", 5.0, 10.0)]
    problems = trace.validate_trace_events(events)
    assert any("overlaps" in p for p in problems)
    # same spans on *different* lanes are fine (that's what lanes are for)
    events2 = [_span("a", 0.0, 10.0), _span("b", 5.0, 10.0, tid=2)]
    assert trace.validate_trace_events(events2) == []


def test_validator_flags_malformed_events():
    bad_ph = {"name": "x", "ph": "Z", "pid": 1, "ts": 0.0}
    assert any("bad ph" in p for p in trace.validate_trace_events([bad_ph]))
    no_ts = {"name": "x", "ph": "X", "pid": 1, "dur": 1.0, "tid": 1}
    assert any("bad ts" in p for p in trace.validate_trace_events([no_ts]))
    neg_dur = _span("x", 0.0, -1.0)
    assert any("bad dur" in p for p in trace.validate_trace_events([neg_dur]))
    no_tid = {"name": "x", "ph": "X", "pid": 1, "ts": 0.0, "dur": 1.0}
    assert any("missing tid" in p
               for p in trace.validate_trace_events([no_tid]))
    no_name = {"ph": "X", "pid": 1, "ts": 0.0, "dur": 1.0, "tid": 1}
    assert any("missing name" in p
               for p in trace.validate_trace_events([no_name]))
    assert any("not a dict" in p
               for p in trace.validate_trace_events(["nope"]))


# ---------------------------------------------------------------------------------
# modeled timeline
# ---------------------------------------------------------------------------------


def test_modeled_timeline_matches_overlap_schedule():
    plan = _plan(_mlp, *MLP_AVALS)
    rows = modeled_timeline(plan)
    assert len(rows) == len(plan.steps)
    makespan = max(r["start_s"] + r["dur_s"] for r in rows)
    assert makespan == pytest.approx(
        plan.opt_report.overlap["overlapped_s"], rel=1e-9)
    # every row carries the taxonomy class of its step, in final step order
    assert [r["cls"] for r in rows] == [step_class(s) for s in plan.steps]
    assert [r["index"] for r in rows] == list(range(len(plan.steps)))
    # comm-only steps land on the interconnect lane, compute on compute
    for r, s in zip(rows, plan.steps):
        if r["comm_s"] > 0.0 and r["compute_s"] == 0.0:
            assert r["lane"] == "interconnect"
        if r["comm_s"] == 0.0:
            assert r["lane"] == "compute"


def test_tracer_modeled_lane_validates_and_offsets_plan_swaps():
    plan = _plan(_mlp, *MLP_AVALS)
    tr = trace.Tracer(trace.TraceConfig(measured=False))
    tr.on_plan(plan)
    first = tr.modeled_events()
    tr.on_plan(plan)  # a swap: second timeline appended after the first
    events = tr.chrome_trace(include_control=False)["traceEvents"]
    assert trace.validate_trace_events(events) == []
    second = [e for e in tr.modeled_events() if e["args"]["plan"] == 1]
    assert len(second) == len(first)
    end_first = max(e["ts"] + e["dur"] for e in first)
    assert all(e["ts"] >= end_first - 1e-6 for e in second)


def test_step_class_taxonomy():
    plan = _plan(_mlp, *MLP_AVALS)
    classes = {step_class(s) for s in plan.steps}
    assert "compute" in classes
    assert classes & {"reshard", "collective"}
    for s in plan.steps:
        if s.inner is not None:
            assert step_class(s).startswith("call:")


# ---------------------------------------------------------------------------------
# traced execution on a real (1-device) runner
# ---------------------------------------------------------------------------------


def _one_device_runner(trace_cfg):
    from repro.core.partitioner import spmd_partition

    m1 = Mesh.create((1, 1), ("x", "y"))
    jmesh = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("x", "y"))

    def f(a, b):
        a = annotate(a, mesh_split(2, m1, ["x", -1]))
        return jnp.tanh(a @ b)

    return spmd_partition(f, jmesh, m1, trace=trace_cfg)


def test_traced_execution_matches_untraced_numerics():
    from repro.core.partitioner import clear_process_plan_cache

    clear_process_plan_cache()
    a = np.random.RandomState(0).randn(8, 8).astype(np.float32)
    b = np.random.RandomState(1).randn(8, 8).astype(np.float32)
    base = _one_device_runner(None)
    traced = _one_device_runner(obs.TraceConfig())
    ref = np.asarray(base(a, b))
    out = np.asarray(traced(a, b))
    np.testing.assert_allclose(out, ref, rtol=1e-6)
    tr = traced.tracer
    assert tr is not None and tr.calls == 1
    (entry,) = traced.plans.values()
    nsteps = len(entry.plan.steps)
    measured = tr.measured_events()
    assert len(measured) == nsteps
    assert {e["args"]["call"] for e in measured} == {0}
    events = tr.chrome_trace()["traceEvents"]
    assert trace.validate_trace_events(events) == []
    # second call appends a second measured pass
    traced(a, b)
    assert tr.calls == 2
    assert len(tr.measured_events()) == 2 * nsteps


def test_disabled_trace_config_is_normalized_away():
    from repro.core.partitioner import (clear_process_plan_cache,
                                        process_plan_cache_stats)

    clear_process_plan_cache()
    a = np.ones((8, 8), np.float32)
    base = _one_device_runner(None)
    off = _one_device_runner(obs.TraceConfig(enabled=False))
    base(a, a)
    off(a, a)  # plans compile lazily on first call: this one must cache-hit
    # disabled config ≡ no tracing: same process-cache entry, no tracer
    assert process_plan_cache_stats().hits >= 1
    assert off.tracer is None and base.tracer is None


def test_trace_requires_compiled_plans():
    from repro.core.partitioner import spmd_partition

    m1 = Mesh.create((1,), ("x",))
    jmesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("x",))
    with pytest.raises(ValueError):
        spmd_partition(lambda a: a, jmesh, m1, compile_plans=False,
                       trace=obs.TraceConfig())


def test_trace_write_roundtrip(tmp_path):
    plan = _plan(_mlp, *MLP_AVALS)
    tr = trace.Tracer(trace.TraceConfig(measured=False))
    tr.on_plan(plan)
    p = tr.write(str(tmp_path / "trace.json"))
    with open(p) as f:
        doc = json.load(f)
    events = doc["traceEvents"]
    assert trace.validate_trace_events(events) == []
    names = {e["args"]["name"] for e in events if e["ph"] == "M"
             and e["name"] == "process_name"}
    assert names == {"modeled", "measured", "control"}


# ---------------------------------------------------------------------------------
# control events
# ---------------------------------------------------------------------------------


def test_control_events_record_and_export():
    obs.reset_control_events()
    trace.control_event("numerics_fault", step=4, consecutive=1)
    trace.control_event("skip_step", step=4)
    evs = obs.control_events()
    assert [e["name"] for e in evs] == ["numerics_fault", "skip_step"]
    assert evs[0]["ts"] <= evs[1]["ts"]
    doc = obs.export_control_trace()
    instants = [e for e in doc["traceEvents"] if e["ph"] == "i"]
    assert [e["name"] for e in instants] == ["numerics_fault", "skip_step"]
    assert all(e["pid"] == trace.CONTROL_PID for e in instants)
    assert trace.validate_trace_events(doc["traceEvents"]) == []
    obs.reset_control_events()
    assert obs.control_events() == []


# ---------------------------------------------------------------------------------
# calibration
# ---------------------------------------------------------------------------------


def test_calibration_joins_by_class_and_normalizes_by_calls():
    events = [
        # modeled: compute 10 µs, collective 100 µs
        _span("m1", 0, 10.0, pid=trace.MODELED_PID, **{"class": "compute"}),
        _span("m2", 10, 100.0, pid=trace.MODELED_PID, tid=2,
              **{"class": "collective"}),
        # measured, 2 calls: compute 20+20 µs, collective 100+100 µs
        _span("x1", 0, 20.0, pid=trace.MEASURED_PID,
              **{"class": "compute", "call": 0}),
        _span("x2", 20, 100.0, pid=trace.MEASURED_PID, tid=2,
              **{"class": "collective", "call": 0}),
        _span("x3", 200, 20.0, pid=trace.MEASURED_PID,
              **{"class": "compute", "call": 1}),
        _span("x4", 220, 100.0, pid=trace.MEASURED_PID, tid=2,
              **{"class": "collective", "call": 1}),
    ]
    rep = calibrate.calibration_report(events, factor=3.0)
    assert rep.calls == 2 and rep.complete
    comp = rep.row("compute")
    # measured totals are per-call: (20+20)/2 = 20 µs → ratio 2, in band
    assert comp.ratio == pytest.approx(2.0)
    assert not comp.flagged
    coll = rep.row("collective")
    assert coll.ratio == pytest.approx(1.0)
    assert rep.flagged == []
    # a dict export works too
    rep2 = calibrate.calibration_report({"traceEvents": events})
    assert rep2.as_dict()["rows"] == rep.as_dict()["rows"]


def test_calibration_flags_out_of_band_classes():
    events = [
        _span("m", 0, 1.0, pid=trace.MODELED_PID, **{"class": "compute"}),
        _span("x", 0, 10.0, pid=trace.MEASURED_PID,
              **{"class": "compute", "call": 0}),
    ]
    rep = calibrate.calibration_report(events, factor=3.0)
    assert rep.row("compute").ratio == pytest.approx(10.0)
    assert rep.flagged == ["compute"]
    # a generous factor un-flags it
    assert calibrate.calibration_report(events, factor=20.0).flagged == []


def test_calibration_zero_modeled_classes_dont_block_completeness():
    events = [
        _span("m", 0, 0.0, pid=trace.MODELED_PID, **{"class": "reshard"}),
        _span("m2", 0, 5.0, pid=trace.MODELED_PID, **{"class": "compute"}),
        _span("x", 0, 7.0, pid=trace.MEASURED_PID,
              **{"class": "compute", "call": 0}),
        _span("x2", 7, 1.0, pid=trace.MEASURED_PID,
              **{"class": "reshard", "call": 0}),
    ]
    rep = calibrate.calibration_report(events)
    assert rep.complete                       # reshard modeled at 0 excluded
    assert rep.row("reshard").ratio is None
    # ...but a priced class with no measured spans breaks completeness
    rep2 = calibrate.calibration_report(events[:3])
    assert not rep2.complete or rep2.row("compute").ratio is not None


def test_calibration_table_renders():
    events = [
        _span("m", 0, 1.0, pid=trace.MODELED_PID, **{"class": "compute"}),
        _span("x", 0, 2.0, pid=trace.MEASURED_PID,
              **{"class": "compute", "call": 0}),
    ]
    t = calibrate.calibration_report(events).table()
    assert "| class |" in t and "| compute |" in t


# ---------------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------------


def test_cli_summarize(tmp_path, capsys):
    from repro.obs.__main__ import main

    reg = metrics.MetricsRegistry()
    reg.inc("a.hits", 3)
    reg.set_gauge("g", 1.5)
    reg.observe("lat_ms", 2.0)
    p = reg.dump(str(tmp_path / "m.json"))
    assert main(["summarize", p]) == 0
    out = capsys.readouterr().out
    assert "a.hits" in out and "lat_ms" in out and "counters" in out


def test_cli_trace_emits_valid_chrome_json(tmp_path, capsys):
    from repro.obs.__main__ import main

    p = str(tmp_path / "trace.json")
    rc = main(["trace", p, "--mesh", "1x2", "--axes", "data,model",
               "--batch", "2", "--seq", "16", "--reduce-k", "4"])
    assert rc == 0
    with open(p) as f:
        doc = json.load(f)
    assert trace.validate_trace_events(doc["traceEvents"]) == []
    assert any(e["ph"] == "X" and e["pid"] == trace.MODELED_PID
               for e in doc["traceEvents"])
    out = capsys.readouterr().out
    assert "steps=" in out and "makespan=" in out
