"""Pipeline subsystem tests (§3.3 stage-stacked pipelining over plans).

Single-device: semantics (bit-identity vs the plain stack, fwd + grads),
plan structure (one first-class ppermute per tick, priced into PlanCost),
ppermute fusion, the schedule cost model, the pipeline decision space, the
soft-memory objective term, and the grad-of-scan (reverse) lowering fix.
Execution parity on real collectives lives in
tests/multidev/test_pipeline_multidev.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Mesh, annotate, mesh_split
from repro.core.plan import compile_plan, plan_cost
from repro.core.propagation import propagate
from repro.core.shift import stage_shift, take_stage_row
from repro.pipeline import (
    PipelineConfig,
    bubble_fraction,
    pipeline_ticks,
    pipelined_apply,
    plan_ppermute_bytes,
    stage_stack_params,
)
from repro.pipeline.schedule import PipelineDecision

rng = np.random.default_rng(0)

L, D, M, MB = 4, 8, 4, 2
WS = jnp.asarray(rng.standard_normal((L, D, D)).astype(np.float32) * 0.3)
XS = jnp.asarray(rng.standard_normal((M, MB, D)).astype(np.float32))


def layer(lp, x, _):
    return jnp.tanh(x @ lp)


def ref_fn(ws, xs):
    def f(h):
        for i in range(ws.shape[0]):
            h = jnp.tanh(h @ ws[i])
        return h

    return jnp.stack([f(xs[m]) for m in range(xs.shape[0])])


# ---------------------------------------------------------------------------------
# semantics
# ---------------------------------------------------------------------------------


def test_stage_stack_layout_is_contiguous_gpipe():
    stk = stage_stack_params(WS, 2)
    assert stk.shape == (2, 2, D, D)
    np.testing.assert_array_equal(np.asarray(stk[1, 0]), np.asarray(WS[2]))


@pytest.mark.parametrize("S", [1, 2, 4])
def test_pipelined_apply_bit_identical_to_stack(S):
    got = jax.jit(
        lambda w, x: pipelined_apply(layer, w, x, num_stages=S)
    )(stage_stack_params(WS, S), XS)
    ref = jax.jit(ref_fn)(WS, XS)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_pipelined_apply_grads_bit_identical():
    def loss(w, x):
        return jnp.mean(pipelined_apply(layer, w, x, num_stages=2) ** 2)

    def loss_ref(w, x):
        return jnp.mean(ref_fn(w, x) ** 2)

    gw, gx = jax.jit(jax.grad(loss, argnums=(0, 1)))(stage_stack_params(WS, 2), XS)
    rw, rx = jax.jit(jax.grad(loss_ref, argnums=(0, 1)))(WS, XS)
    np.testing.assert_array_equal(np.asarray(gw).reshape(L, D, D), np.asarray(rw))
    np.testing.assert_array_equal(np.asarray(gx), np.asarray(rx))


# ---------------------------------------------------------------------------------
# plan structure: the per-tick ppermute is a first-class, priced step
# ---------------------------------------------------------------------------------


def _pipelined_plan(S=4, M=4):
    mesh = Mesh.create((S,), ("stage",))
    xs = jnp.asarray(rng.standard_normal((M, MB, D)).astype(np.float32))

    def fn(wstk, xs):
        wstk = annotate(wstk, mesh_split(4, mesh, ["stage", -1, -1, -1]))
        ys = pipelined_apply(layer, wstk, xs, num_stages=S,
                             mesh=mesh, stage_axis="stage")
        return jnp.mean(ys ** 2)

    closed = jax.make_jaxpr(fn)(
        jax.ShapeDtypeStruct((S, L // S, D, D), jnp.float32),
        jax.ShapeDtypeStruct((M, MB, D), jnp.float32),
    )
    prop = propagate(closed, mesh).result()
    return compile_plan(closed, prop, mesh, cost_only=True), mesh


def _scan_step(plan):
    steps = [s for s in plan.steps if s.op == "scan" and s.inner is not None]
    assert len(steps) == 1, [s.op for s in plan.steps]
    return steps[0]


def test_each_tick_issues_exactly_one_ppermute():
    plan, _ = _pipelined_plan(S=4, M=4)
    scan = _scan_step(plan)
    assert scan.call["trips"] == pipeline_ticks(4, 4)
    pperms = [s for s in scan.inner.steps
              if s.kind == "collective" and s.op == "ppermute"]
    assert len(pperms) == 1
    (pp,) = pperms
    assert pp.axes == ("stage",)
    # GPipe forward shift: each device sends its boundary row right
    assert pp.call["perm"] == tuple((i, i + 1) for i in range(3))
    # the per-tick output collection is a first-class psum, also one per tick
    psums = [s for s in scan.inner.steps if s.kind == "collective"
             and s.op != "ppermute"]
    assert len(psums) == 1 and psums[0].reduce_op == "add"


def test_ppermute_priced_into_plan_cost():
    plan, _ = _pipelined_plan(S=4, M=4)
    scan = _scan_step(plan)
    ticks = scan.call["trips"]
    (pp,) = [s for s in scan.inner.steps
             if s.kind == "collective" and s.op == "ppermute"]
    # boundary row: one stage slot of the local state
    assert pp.in_bytes == MB * D * 4
    pbytes, launches = plan_ppermute_bytes(plan)
    assert launches == ticks
    assert pbytes == pytest.approx(ticks * pp.in_bytes)
    cost = plan_cost(plan)
    # whole-program collective pricing (trip-multiplied) must cover them
    assert cost.wire_bytes >= pbytes
    assert cost.launches >= launches


def test_same_perm_ppermutes_fuse():
    """Two independent boundary hops with the same (axis, perm) share one
    fused launch once adjacent (the pass's own placement legality applies)."""
    from repro.core.plan_opt import fuse_collectives

    mesh = Mesh.create((4,), ("stage",))

    def fn(a, b, x, y):
        a = annotate(a, mesh_split(2, mesh, ["stage", -1]))
        b = annotate(b, mesh_split(2, mesh, ["stage", -1]))
        return stage_shift(a, x) + stage_shift(b, y)

    closed = jax.make_jaxpr(fn)(
        jax.ShapeDtypeStruct((4, 3), jnp.float32),
        jax.ShapeDtypeStruct((4, 3), jnp.float32),
        jax.ShapeDtypeStruct((3,), jnp.float32),
        jax.ShapeDtypeStruct((3,), jnp.float32),
    )
    prop = propagate(closed, mesh).result()
    plan = compile_plan(closed, prop, mesh, cost_only=True, optimize=False)
    # emission interleaves slice/ppermute/stitch per shift; reorder the two
    # shifts' steps so the ppermutes are adjacent (write-before-read holds:
    # aliases, then both boundary slices, then both hops, then consumers)
    order = {("compute", "annotate"): 0, ("compute", "alias"): 0,
             ("compute", "shift-boundary"): 1, ("collective", "ppermute"): 2}
    plan.steps.sort(key=lambda s: order.get((s.kind, s.op), 3))
    rep = fuse_collectives(plan)
    assert rep.fused_buckets == 1 and rep.fused_members == 2
    fused = [s for s in plan.steps if s.op == "fused-ppermute"]
    assert len(fused) == 1
    assert fused[0].call["perm"] == tuple((i, i + 1) for i in range(3))


def test_grad_of_scan_lowers_reverse():
    """Regression: grad-of-scan is a reverse scan; the plan runner must
    replay it back to front (found by the pipeline backward, which reads a
    different cotangent microbatch every tick)."""
    from jax import lax

    mesh = Mesh.create((1,), ("x",))

    def f(xs):
        def body(c, x):
            return c * 0.5 + x, c

        c, ys = lax.scan(body, jnp.float32(0.0), xs)
        return c + jnp.sum(ys * jnp.arange(4.0, dtype=jnp.float32))

    xs = jnp.arange(4.0, dtype=jnp.float32)
    closed = jax.make_jaxpr(jax.grad(f))(xs)
    prop = propagate(closed, mesh).result()
    plan = compile_plan(closed, prop, mesh)
    (got,) = plan.execute(xs)
    (want,) = (jax.grad(f)(xs),)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


# ---------------------------------------------------------------------------------
# schedule cost model
# ---------------------------------------------------------------------------------


def test_bubble_fraction_closed_form():
    assert bubble_fraction(4, 4) == pytest.approx(3 / 7)
    assert bubble_fraction(1, 8) == 0.0
    assert pipeline_ticks(4, 4) == 7
    d = PipelineDecision("stage", 4, 4)
    assert d.bubble == pytest.approx(3 / 7) and d.ticks == 7


def test_bubble_shows_up_as_compute_inflation():
    """All stages compute every tick, so modeled per-device FLOPs of the
    pipelined plan are (M + S − 1)/M × the useful per-microbatch work."""
    plan4, _ = _pipelined_plan(S=4, M=4)
    plan4b, _ = _pipelined_plan(S=4, M=8)
    f4 = plan_cost(plan4).flops_per_device
    f4b = plan_cost(plan4b).flops_per_device
    # per-tick flops are equal; tick counts are 7 vs 11
    assert f4b / f4 == pytest.approx(11 / 7, rel=0.02)


def test_schedule_cost_summary():
    from repro.pipeline.schedule import schedule_cost

    S, M = 4, 4
    mesh = Mesh.create((S,), ("stage",))
    dec = PipelineDecision("stage", S, M)

    def fn(wstk, xs):
        wstk = annotate(wstk, mesh_split(4, mesh, ["stage", -1, -1, -1]))
        ys = pipelined_apply(layer, wstk, xs, num_stages=S,
                             mesh=mesh, stage_axis="stage")
        return jnp.mean(ys ** 2)

    closed = jax.make_jaxpr(fn)(
        jax.ShapeDtypeStruct((S, L // S, D, D), jnp.float32),
        jax.ShapeDtypeStruct((M, MB, D), jnp.float32),
    )
    sc = schedule_cost(closed, [None, None], mesh, dec,
                       state_shape=(S, MB, D))
    assert sc.bubble == pytest.approx(bubble_fraction(S, M))
    assert sc.ppermute_launches == pipeline_ticks(S, M)
    assert sc.ppermute_bytes > 0
    # stage dim sharded: one stage row per device
    assert sc.microbatch_activation_bytes == MB * D * 4
    assert sc.total_s > 0
    rec = sc.as_dict()
    assert rec["bubble_fraction"] == sc.bubble


# ---------------------------------------------------------------------------------
# decision space + memory term
# ---------------------------------------------------------------------------------


def test_pipeline_decisions_enumeration():
    from repro.autoshard.space import pipeline_decisions

    mesh = Mesh.create((2, 4), ("data", "model"))
    decs = pipeline_decisions(mesh, num_layers=4, batch=8,
                              pcfg=PipelineConfig(max_stages=4))
    got = {(d.stage_axis, d.num_stages, d.num_microbatches) for d in decs}
    # data(2): S in {2, 4}; model(4): S = 4; M in {2, 4}; all divide L=4, B=8
    assert got == {
        ("data", 2, 2), ("data", 2, 4), ("data", 4, 2), ("data", 4, 4),
        ("model", 4, 2), ("model", 4, 4),
    }
    # stage counts must divide the layer count
    decs3 = pipeline_decisions(mesh, num_layers=6, batch=8,
                               pcfg=PipelineConfig(max_stages=4))
    assert {(d.stage_axis, d.num_stages) for d in decs3} == {("data", 2)}
    # microbatches must divide the batch
    decs5 = pipeline_decisions(mesh, num_layers=4, batch=6,
                               pcfg=PipelineConfig(max_stages=2))
    assert all(d.num_microbatches == 2 for d in decs5)


def test_solve_with_pipeline_returns_mixed_assignment():
    """ISSUE-5 acceptance: ``autoshard.solve(..., pipeline=PipelineConfig
    (max_stages=4))`` on a 2×4 mesh returns a pipeline+tensor point whose
    modeled cost is at or below the best pure-tensor assignment.  The budget
    sits below the pure-tensor search's feasible floor (its activation peak
    cannot fit), while the pipelined rewrite fits — the §3.3 microbatched
    shifting buffer holds one microbatch per stage row."""
    from repro import autoshard

    mesh = Mesh.create((2, 4), ("data", "model"))
    cfg = autoshard.AutoshardConfig(
        budget_bytes=35e6, top_n=2, sa_steps=2, beam_width=2,
        max_candidates=6,
    )
    kw = dict(batch=4, seq=32, reduce_k=6)
    pure = autoshard.solve("qwen1.5-0.5b", mesh, cfg, **kw)
    res = autoshard.solve(
        "qwen1.5-0.5b", mesh, cfg, **kw,
        pipeline=PipelineConfig(max_stages=4, num_microbatches=2,
                                stage_axes=("model",)),
    )
    assert res.pipeline is not None, "no pipeline decision chosen"
    assert res.evaluation.feasible
    assert res.evaluation.score <= pure.evaluation.score
    assert res.pipeline["stage_axis"] == "model"
    assert res.pipeline["num_stages"] == 4
    assert res.pipeline["bubble_fraction"] == pytest.approx(
        bubble_fraction(4, 2))
    assert res.pipeline["ppermute_launches"] == pipeline_ticks(4, 2)
    # mixed pipeline+tensor: the assignment tensor-shards on a non-stage axis
    assert any(
        s is not None and any(
            a != "model" for dm in s.dims_mapping for a in dm)
        for s in res.assignment
    )
    # the decision round-trips through the JSON dump
    rec = res.to_json()
    assert rec["pipeline"]["num_microbatches"] == 2


def test_mem_term_breaks_pipeline_search_tie():
    """Satellite: the soft-memory objective term.  A pipelined step that
    threads the NEXT microbatch buffer through untouched (prefetch) has a
    genuine roofline tie: sharding the buffer moves zero wire bytes and zero
    FLOPs, so with the term off the greedy sweep keeps the replication
    default; with the term on, the lower-peak assignment strictly wins."""
    from repro import autoshard

    S, M_ = 4, 4
    mesh = Mesh.create((S,), ("stage",))

    def fn(wstk, xs, prefetch):
        ys = pipelined_apply(layer, wstk, xs, num_stages=S,
                             mesh=mesh, stage_axis="stage")
        return jnp.mean(ys ** 2)

    closed = jax.make_jaxpr(fn)(
        jax.ShapeDtypeStruct((S, L // S, D, D), jnp.float32),
        jax.ShapeDtypeStruct((M_, MB, D), jnp.float32),
        jax.ShapeDtypeStruct((64, MB, D), jnp.float32),  # largest invar
    )
    cfg = dict(top_n=1, sa_steps=0, max_candidates=8)
    off = autoshard.solve_problem(
        closed, mesh, autoshard.AutoshardConfig(**cfg))
    on = autoshard.solve_problem(
        closed, mesh,
        autoshard.AutoshardConfig(mem_weight=1.0, soft_budget_bytes=0.0,
                                  **cfg))
    assert off.evaluation.cost.mem_s == 0.0
    assert on.evaluation.cost.mem_s > 0.0
    # the tie: scores identical under the pure roofline objective...
    base_terms = off.evaluation.cost
    picked = on.evaluation.cost
    assert picked.wire_bytes == base_terms.wire_bytes
    assert picked.flops_per_device == base_terms.flops_per_device
    # ...so only the memory term separates them, and it picks the lower peak
    assert picked.peak_bytes < base_terms.peak_bytes
    # with the term off, the prefetch buffer stayed with propagation (None)
    assert off.assignment[2] is None
    assert on.assignment[2] is not None
