"""Pipeline-as-sharding tests (paper §3.3, Tables 4-5)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pipeline import (
    circular_bubble_ratio, gpipe_bubble_ratio, pipeline,
)

rng = np.random.default_rng(0)


def _seq_ref(ws, xs, L, R):
    out = []
    for m in range(xs.shape[0]):
        h = xs[m]
        for r in range(R):
            for s in range(L):
                h = np.tanh(h @ ws[s, r])
        out.append(h)
    return np.stack(out)


def stage_fn(w, x):
    return jnp.tanh(x @ w)


@pytest.mark.parametrize("L,R,M", [(4, 1, 8), (4, 2, 8), (2, 3, 6), (8, 4, 16)])
def test_pipeline_matches_sequential(L, R, M):
    D = 8
    ws = rng.standard_normal((L, R, D, D)).astype(np.float32) * 0.2
    xs = rng.standard_normal((M, 2, D)).astype(np.float32)
    got = pipeline(stage_fn, jnp.asarray(ws), jnp.asarray(xs),
                   num_stages=L, num_rounds=R)
    np.testing.assert_allclose(np.asarray(got), _seq_ref(ws, xs, L, R),
                               rtol=1e-4, atol=1e-5)


def test_pipeline_differentiable_with_remat():
    L, R, M, D = 2, 2, 4, 8
    ws = jnp.asarray(rng.standard_normal((L, R, D, D)).astype(np.float32) * 0.2)
    xs = jnp.asarray(rng.standard_normal((M, 2, D)).astype(np.float32))

    def loss(ws):
        out = pipeline(stage_fn, ws, xs, num_stages=L, num_rounds=R, remat=True)
        return jnp.sum(out ** 2)

    g = jax.grad(loss)(ws)
    assert np.isfinite(np.asarray(g)).all()
    assert float(jnp.abs(g).sum()) > 0


def test_bubble_ratios_match_paper_table5():
    """Conformer Table 5: L=8 stages. GPipe M=64 -> 9.6%; GPipe M=16 -> 29.9%;
    circular M=16, R=4 (32 layers / 8 stages) -> 9.0%. Our closed forms give
    9.9% / 30.4% / 9.9% — within ~1.5 points (the paper measures step-time
    shares, we count schedule slots)."""
    assert abs(gpipe_bubble_ratio(8, 64) - 0.096) < 0.015
    assert abs(gpipe_bubble_ratio(8, 16) - 0.299) < 0.02
    assert abs(circular_bubble_ratio(8, 16, 4) - 0.090) < 0.015


def test_circular_beats_gpipe_at_same_microbatches():
    """The paper's point: circular reaches GPipe-with-4x-microbatches bubbles."""
    assert circular_bubble_ratio(8, 16, 4) < gpipe_bubble_ratio(8, 16) / 2
    assert abs(circular_bubble_ratio(8, 16, 4) - gpipe_bubble_ratio(8, 64)) < 0.01
