"""Chaos soak harness: seed-deterministic campaign generation, JSON
round-trip, one real single-device soak with the full invariant battery, and
the replay-identical contract.  The heavier multi-event soaks run via the
CLI / bench cell (``python -m repro.launch.chaos``)."""
import json

from repro.launch.chaos import (
    DEFAULT_KINDS,
    CampaignSpec,
    generate_campaign,
    replay_identical,
    run_campaign,
)


def test_generate_campaign_is_seed_deterministic():
    a = generate_campaign(11, steps=30, n_events=5)
    b = generate_campaign(11, steps=30, n_events=5)
    assert a.schedule == b.schedule
    c = generate_campaign(12, steps=30, n_events=5)
    assert [e["kind"] for e in a.schedule] != [e["kind"] for e in c.schedule]
    # events are spaced so every event has an intact checkpoint behind it
    steps = [e["step"] for e in a.schedule]
    assert steps == sorted(steps)
    assert all(t2 - t1 >= a.ckpt_every + 2 for t1, t2 in zip(steps, steps[1:]))


def test_generate_campaign_legality_rules():
    # a return is only legal once devices are out; straggler fires once
    for seed in range(24):
        spec = generate_campaign(seed, steps=80, n_events=10, world=8)
        out = 0
        stragglers = 0
        for ev in spec.schedule:
            assert ev["kind"] in DEFAULT_KINDS
            if ev["kind"] == "device_loss":
                out += ev["lose"]
            elif ev["kind"] == "device_return":
                assert out > 0, f"seed {seed}: return with no devices out"
                out -= ev["gain"]
                assert out >= 0
            elif ev["kind"] == "straggler":
                stragglers += 1
        assert stragglers <= 1


def test_campaign_spec_json_round_trip(tmp_path):
    spec = generate_campaign(7, steps=20, n_events=4, world=4)
    p = str(tmp_path / "campaign.json")
    spec.to_json(p)
    again = CampaignSpec.from_json(p)
    assert again == spec
    with open(p) as f:
        assert json.load(f)["version"] == 1


def test_soak_holds_invariants_and_replays(tmp_path):
    """Acceptance drill: a seeded 3-event soak (shrink → NaN burst → regrow,
    the 1-device lose=0/gain=0 edition) finishes with zero invariant
    violations, and the identical spec replays to the identical deterministic
    control-event signature."""
    spec = CampaignSpec(seed=42, steps=14, ckpt_every=2, schedule=[
        {"kind": "device_loss", "step": 3, "lose": 0},
        {"kind": "nan_burst", "step": 7, "steps": 1},
        {"kind": "device_return", "step": 11, "gain": 0},
    ])
    same, a, b = replay_identical(spec, str(tmp_path))
    assert a.violations == []
    assert a.losses == 14
    assert same, "replay produced a different control-event signature"
    # the three injections each produced a recovery, single restore each
    assert len(a.recoveries) == 3
    assert all("restored_from" in r for r in a.recoveries)
    assert [ep["restores"] for ep in a.narrative] == [1, 1, 1]
    # spec stayed pristine (the injector annotates a deep copy)
    assert all("corrupted_step" not in e for e in spec.schedule)


def test_soak_flags_deliberate_corruption_without_violations(tmp_path):
    """manifest_corrupt immediately before a rewind: the restore must fall
    back past the (deliberately) corrupted newest step in the same single
    pass, the corrupted step is known from the campaign annotations, and the
    invariant battery still reports a clean soak."""
    spec = CampaignSpec(seed=1, steps=12, ckpt_every=2, schedule=[
        {"kind": "manifest_corrupt", "step": 7},
        {"kind": "nan_burst", "step": 7, "steps": 1},
    ])
    report = run_campaign(spec, str(tmp_path))
    assert report.violations == []
    # the rewind at 7 had to fall back past the corrupted newest step
    rec = [r for r in report.recoveries if "restored_from" in r]
    assert rec and any(r.get("fell_back_from") for r in rec)
    assert any("corrupt_checkpoint" in r["classes"] for r in rec)
