"""Sharding auto-completion tests (paper §3.2/§3.5, Figures 3-4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Mesh, annotate, mesh_split, propagate

mesh = Mesh.create((2, 4), ("x", "y"))


def out_sharding(fn, *args):
    closed = jax.make_jaxpr(fn)(*args)
    prop = propagate(closed, mesh)
    return [prop.get(v) for v in closed.jaxpr.outvars], prop, closed


def test_dot_merge_figure3():
    """§3.2: bd(x,_) × df(_,y) -> bf(x,y) — merged from both inputs."""

    def f(bd, df):
        bd = annotate(bd, mesh_split(2, mesh, ["x", -1]))
        df = annotate(df, mesh_split(2, mesh, [-1, "y"]))
        return jnp.dot(bd, df)

    (s,), _, _ = out_sharding(f, jnp.ones((8, 16)), jnp.ones((16, 32)))
    assert s.dims_mapping == (("x",), ("y",))


def test_elementwise_priority_figure4():
    """Figure 4: the BD-shaped tensors around an elementwise op all get the
    same sharding (elementwise has the highest priority)."""

    def f(x, w):
        x = annotate(x, mesh_split(2, mesh, ["x", -1]))
        w = annotate(w, mesh_split(2, mesh, [-1, "y"]))
        y = jnp.dot(x, w)
        z = jnp.tanh(y)  # elementwise: must match y
        return y, z

    (sy, sz), _, _ = out_sharding(f, jnp.ones((4, 8)), jnp.ones((8, 8)))
    assert sy.dims_mapping == sz.dims_mapping == (("x",), ("y",))


def test_backward_propagation_through_broadcast():
    def f(b):
        big = jnp.broadcast_to(b[None, :], (16, 8))
        return annotate(big, mesh_split(2, mesh, ["x", "y"]))

    closed = jax.make_jaxpr(f)(jnp.ones(8))
    prop = propagate(closed, mesh)
    (invar,) = closed.jaxpr.invars
    s = prop.get(invar)
    assert s is not None and s.dims_mapping == (("y",),)


def test_annotation_preserved():
    """User annotations are never overwritten (§3.5)."""

    def f(x):
        x = annotate(x, mesh_split(2, mesh, ["y", -1]))
        return x * 2.0

    (s,), prop, closed = out_sharding(f, jnp.ones((8, 8)))
    assert s.dims_mapping[0] == ("y",)


def test_partial_specification():
    """unspecified_dims may be refined by propagation (§3.5)."""

    def f(x, w):
        x = annotate(x, mesh_split(2, mesh, ["x", -1]), unspecified_dims=[1])
        w = annotate(w, mesh_split(2, mesh, [-1, "y"]))
        y = x @ w
        return annotate(y, mesh_split(2, mesh, ["x", "y"]))

    closed = jax.make_jaxpr(f)(jnp.ones((8, 8)), jnp.ones((8, 8)))
    prop = propagate(closed, mesh)
    # backward through dot can refine x's unspecified dim... at minimum the
    # locked dim 0 stays "x"
    s = prop.get(closed.jaxpr.invars[0])
    assert s.dims_mapping[0] == ("x",)


def test_scan_carry_fixed_point():
    def f(x, ws):
        x = annotate(x, mesh_split(2, mesh, ["x", -1]))

        def body(c, w):
            w = annotate(w, mesh_split(2, mesh, [-1, "y"]))
            return jnp.tanh(c @ w), ()

        y, _ = jax.lax.scan(body, x, ws)
        return y

    (s,), prop, closed = out_sharding(f, jnp.ones((8, 16)), jnp.ones((3, 16, 16)))
    assert s is not None and s.dims_mapping[0] == ("x",)
    # the stacked weights invar gets (none, -1, y)
    ws_sh = prop.get(closed.jaxpr.invars[1])
    assert ws_sh.dims_mapping == ((), (), ("y",))


def test_grad_of_annotation_is_annotated():
    """§3.6: gradient of XlaSharding is a copy of itself."""

    def f(w, x):
        w = annotate(w, mesh_split(2, mesh, ["x", "y"]))
        return jnp.sum(jnp.tanh(x @ w))

    closed = jax.make_jaxpr(jax.grad(f))(jnp.ones((8, 8)), jnp.ones((4, 8)))
    prop = propagate(closed, mesh)
    (g,) = [prop.get(v) for v in closed.jaxpr.outvars]
    assert g.dims_mapping == (("x",), ("y",))


def test_fixed_point_idempotent():
    """Running propagation on an already-completed env changes nothing."""

    def f(x, w):
        x = annotate(x, mesh_split(2, mesh, ["x", -1]))
        w = annotate(w, mesh_split(2, mesh, [-1, "y"]))
        return jax.nn.relu(x @ w)

    closed = jax.make_jaxpr(f)(jnp.ones((4, 8)), jnp.ones((8, 8)))
    prop = propagate(closed, mesh)
    snapshot = {v: s.dims_mapping for v, s in prop.env.items()}
    prop.run(max_rounds=4)
    assert {v: s.dims_mapping for v, s in prop.env.items()} == snapshot


def test_transpose_reshape_reduce_chain():
    def f(x):
        x = annotate(x, mesh_split(3, mesh, ["x", -1, "y"]))
        y = jnp.transpose(x, (2, 0, 1))
        z = y.reshape(y.shape[0], -1)
        return z.sum(axis=1)

    (s,), _, _ = out_sharding(f, jnp.ones((4, 3, 8)))
    assert s.dims_mapping == (("y",),)


def test_gspmd_jit_numeric():
    from repro.core import gspmd_jit

    m1 = Mesh.create((1, 1), ("x", "y"))
    from repro.core.compat import make_jax_mesh

    jm = make_jax_mesh((1, 1), ("x", "y"))

    def f(a, b):
        a = annotate(a, mesh_split(2, m1, ["x", -1]))
        b = annotate(b, mesh_split(2, m1, [-1, "y"]))
        return jax.nn.relu(a @ b)

    rng = np.random.default_rng(0)
    a = rng.standard_normal((8, 16)).astype(np.float32)
    b = rng.standard_normal((16, 8)).astype(np.float32)
    out = gspmd_jit(f, jm, m1)(a, b)
    np.testing.assert_allclose(np.asarray(out), np.maximum(a @ b, 0), rtol=1e-5)


def test_annotation_counting_seven_per_layer():
    """§5.1: ~7 annotations per Transformer layer complete the whole graph.
    We assert propagation covers >90% of jaxpr vars from the strategy's
    annotations on a reduced dense layer graph."""
    from repro.configs.base import ModelConfig, get_strategy
    from repro.models import api
    from repro.models.layers import tree_init

    cfg = ModelConfig(
        name="t", family="dense", num_layers=2, d_model=32, num_heads=4,
        num_kv_heads=4, d_ff=64, vocab_size=64, attn_chunk=16, remat="none",
        scan_layers=False,
    )
    st = get_strategy("2d_finalized")
    params = tree_init(api.param_tree(cfg, st), jax.random.PRNGKey(0))
    tok = jnp.zeros((2, 16), jnp.int32)
    closed = jax.make_jaxpr(
        lambda p: api.loss_fn(cfg, st, p, {"tokens": tok, "labels": tok})
    )(params)
    # the graph traces fine; annotation sites are with_sharding_constraint which
    # requires a mesh context — this test just asserts the graph is completable
    prop = propagate(closed, mesh)
    assert prop is not None
