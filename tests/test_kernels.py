"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import attention
from repro.kernels.ref import attention_ref, ssd_scan_ref
from repro.kernels.ssd_scan import ssd_scan

rng = np.random.default_rng(0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Hq,Hkv,S,D,bq,bk",
    [
        (1, 2, 2, 128, 64, 64, 64),
        (2, 4, 2, 256, 64, 128, 128),
        (1, 8, 2, 128, 128, 64, 32),
        (2, 2, 1, 256, 32, 128, 64),
    ],
)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, Hq, Hkv, S, D, bq, bk, causal, dtype):
    tol = 2e-3 if dtype == jnp.float32 else 2e-2
    q = jnp.asarray(rng.standard_normal((B, Hq, S, D)), dtype)
    k = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), dtype)
    v = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), dtype)
    out = attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    ref = attention_ref(q, k, v, causal=causal, group_size=Hq // Hkv)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=tol, atol=tol * 5,
    )


@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize(
    "B,S,H,hd,ds,chunk",
    [
        (1, 128, 2, 64, 64, 64),
        (2, 256, 3, 64, 128, 128),
        (1, 256, 1, 32, 16, 128),
    ],
)
def test_ssd_sweep(B, S, H, hd, ds, chunk, dtype):
    x = jnp.asarray(rng.standard_normal((B, S, H, hd)), dtype)
    dt = jnp.asarray(np.abs(rng.standard_normal((B, S, H))) * 0.5, dtype)
    Bm = jnp.asarray(rng.standard_normal((B, S, ds)) * 0.2, dtype)
    Cm = jnp.asarray(rng.standard_normal((B, S, ds)) * 0.2, dtype)
    A = jnp.asarray(-np.abs(rng.standard_normal((H,))), jnp.float32)
    got = ssd_scan(x, dt, Bm, Cm, A, chunk=chunk)
    ref = ssd_scan_ref(x, dt, Bm, Cm, A, chunk=chunk)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref), rtol=3e-3, atol=3e-3
    )


def test_ssd_kernel_matches_sequential_recurrence():
    """End-to-end: kernel == chunked ref == exact sequential recurrence."""
    B, S, H, hd, ds = 1, 64, 2, 16, 8
    x = rng.standard_normal((B, S, H, hd)).astype(np.float32)
    dt = np.abs(rng.standard_normal((B, S, H))).astype(np.float32) * 0.5
    Bm = rng.standard_normal((B, S, ds)).astype(np.float32)
    Cm = rng.standard_normal((B, S, ds)).astype(np.float32)
    A = -np.abs(rng.standard_normal((H,))).astype(np.float32)
    y_seq = np.zeros_like(x)
    for b in range(B):
        state = np.zeros((H, hd, ds))
        for t in range(S):
            a = np.exp(dt[b, t] * A)
            state = a[:, None, None] * state + dt[b, t][:, None, None] * np.einsum(
                "hp,d->hpd", x[b, t], Bm[b, t]
            )
            y_seq[b, t] = np.einsum("hpd,d->hp", state, Cm[b, t])
    got = ssd_scan(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(Bm),
                   jnp.asarray(Cm), jnp.asarray(A), chunk=32)
    np.testing.assert_allclose(np.asarray(got), y_seq, rtol=2e-4, atol=2e-4)
