"""HLO parsing + roofline math tests."""
import numpy as np
import pytest

from repro.analysis.hlo_parse import collective_bytes, parse_collectives
from repro.analysis.roofline import (
    HBM_BW, ICI_BW, PEAK_FLOPS, RooflineTerms, count_params, extrapolate,
    model_flops, terms_from_artifact,
)
from repro.configs.registry import get_config

HLO = """
HloModule test
ENTRY main {
  %p = bf16[16,512]{1,0} parameter(0)
  %ag = bf16[16,8192]{1,0} all-gather(bf16[16,512]{1,0} %p), replica_groups={{0,1,2,3}}, dimensions={1}
  %ar = f32[128,64]{1,0} all-reduce(f32[128,64]{1,0} %x), replica_groups=[16,16]<=[256], to_apply=%add
  %rs = f32[8,64]{1,0} reduce-scatter(f32[128,64]{1,0} %y), replica_groups={{0,1}}, dimensions={0}
  %a2a = bf16[4,32]{1,0} all-to-all(bf16[4,32]{1,0} %z), replica_groups={{0,1,2,3}}
  %cp = bf16[4,32]{1,0} collective-permute(bf16[4,32]{1,0} %w), source_target_pairs={{0,1}}
  %ags = bf16[16,8192]{1,0} all-gather-start(bf16[16,512]{1,0} %p2), replica_groups={{0,1,2,3}}
}
"""


def test_parse_collectives():
    recs = parse_collectives(HLO)
    kinds = [r["kind"] for r in recs]
    assert kinds.count("all-gather") == 2  # includes the -start variant
    assert kinds.count("all-reduce") == 1
    ag = next(r for r in recs if r["kind"] == "all-gather")
    assert ag["result_bytes"] == 16 * 8192 * 2
    assert ag["operand_bytes"] == 16 * 512 * 2
    assert ag["group_size"] == 4
    assert abs(ag["wire_bytes"] - 16 * 8192 * 2 * 3 / 4) < 1
    ar = next(r for r in recs if r["kind"] == "all-reduce")
    assert ar["group_size"] == 16  # iota format [16,16]<=[256]
    assert abs(ar["wire_bytes"] - 2 * 128 * 64 * 4 * 15 / 16) < 1
    summary = collective_bytes(HLO)
    assert summary["count"] == len(recs)
    assert summary["wire_bytes"] > 0


def test_extrapolate_delta_trick():
    # per-layer cost 7, base 3, L=24: q(1)=10, q(2)=17 -> total 3+24*7=171
    assert extrapolate(10, 17, 1, 2, 24) == pytest.approx(171)
    # flat (no scan contribution)
    assert extrapolate(10, 10, 1, 2, 24) == pytest.approx(10)


def test_roofline_terms():
    t = RooflineTerms(
        compute_s=0.1, memory_s=0.02, collective_s=0.3,
        hlo_flops_per_dev=0.1 * PEAK_FLOPS, hlo_bytes_per_dev=0.02 * HBM_BW,
        wire_bytes_per_dev=0.3 * ICI_BW, model_flops_total=0.05 * PEAK_FLOPS * 256,
        chips=256,
    )
    assert t.dominant == "collective"
    assert t.step_time_s == pytest.approx(0.3)
    assert 0 < t.mfu < 1


def test_param_counts_sane():
    # qwen 0.5b: total params in [0.4B, 0.8B]
    p = count_params(get_config("qwen1.5-0.5b"))
    assert 3e8 < p["total"] < 8e8
    # nemotron 340b within 25%
    p = count_params(get_config("nemotron-4-340b"))
    assert 2.6e11 < p["total"] < 4.3e11
    # llama4 maverick: ~400B total, ~17B active
    p = count_params(get_config("llama4-maverick-400b-a17b"))
    assert 2.5e11 < p["total"] < 5.5e11
    assert 0.8e10 < p["active"] < 3e10
    # granite: ~1.3B total ~400M active
    p = count_params(get_config("granite-moe-1b-a400m"))
    assert 0.6e9 < p["total"] < 2.5e9
    assert p["active"] < 0.9e9
    # jamba 398B
    p = count_params(get_config("jamba-1.5-large-398b"))
    assert 2.5e11 < p["total"] < 5.5e11


def test_model_flops_train_vs_decode():
    cfg = get_config("qwen1.5-0.5b")
    f_train = model_flops(cfg, "train", 256, 4096)
    f_dec = model_flops(cfg, "decode", 128, 32768)
    assert f_train > f_dec
    p = count_params(cfg)
    assert f_train == pytest.approx(6 * p["active"] * 256 * 4096)
