"""Machine-profile fitting (`repro.obs.profile`): the calibration loop.

Covers the PR-10 acceptance surface: planted-constant recovery within the
named tolerance class (the fitter must invert its own forward model),
robust outlier rejection, JSON round-trips for `RooflineParams` and
`MachineProfile`, profile resolution precedence (explicit arg > env var >
nothing), fit-residual / staleness gauges in the metrics registry,
tight-timed traced execution (numerics identical, spans non-overlapping),
plan-cache isolation (same jaxpr under two profiles -> two process-cache
entries; profile-off shares one), default-params bit-identity of
`PlanCost`, re-scoring criteria, `CalibrationReport` joins, and memory
telemetry.
"""
import dataclasses
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(__file__))

from repro import obs
from repro.analysis.roofline import DEFAULT_PARAMS, RooflineParams
from repro.core import Mesh, annotate, mesh_split, propagate
from repro.core.compat import assert_close
from repro.core.plan import compile_plan, plan_cost
from repro.obs import calibrate, metrics, trace
from repro.obs.profile import (MachineProfile, StepSample, collect_samples,
                               fit_profile, memory_report, rescore_report,
                               resolve_profile)

PLANTED = RooflineParams(peak_flops=1.5e13, ici_bw=2.5e10,
                         collective_launch_s=2.5e-5)

# (class, flops, wire_bytes, launches): two compute classes spanning a 16x
# flops range plus three collective shapes, so all three fitted columns are
# well determined
_FEATS = (
    ("einsum", 2e9, 0.0, 0.0), ("einsum", 8e9, 0.0, 0.0),
    ("eltwise", 5e8, 0.0, 0.0),
    ("reshard", 0.0, 4e6, 1.0), ("reshard", 0.0, 3.2e7, 1.0),
    ("reshard", 0.0, 1e5, 2.0),
)


def _planted_samples(params=PLANTED):
    out = []
    for cls, fl, wb, la in _FEATS:
        s = StepSample(cls=cls, flops=fl, wire_bytes=wb, launches=la,
                       measured_s=0.0)
        out.append(dataclasses.replace(s, measured_s=s.modeled_s(params)))
    return out


# ---------------------------------------------------------------------------------
# fitting
# ---------------------------------------------------------------------------------


def test_fit_recovers_planted_constants():
    prof = fit_profile(_planted_samples(), source="test")
    assert set(prof.fitted) == {"peak_flops", "ici_bw",
                                "collective_launch_s"}
    planted = PLANTED.as_dict()
    fitted = prof.params.as_dict()
    for k in prof.fitted:
        assert_close(fitted[k], planted[k], kind="f32",
                     err_msg=f"constant {k}")
    # unobservable fields keep their defaults
    assert fitted["hbm_bw"] == DEFAULT_PARAMS.hbm_bw
    assert fitted["overlap_efficiency"] == DEFAULT_PARAMS.overlap_efficiency
    # exact system: every class residual ratio is 1, nothing flagged
    for cls, ratio in prof.residuals.items():
        assert_close(ratio, 1.0, kind="f32", err_msg=f"residual {cls}")
    assert prof.flagged == []
    assert prof.dropped == 0
    assert prof.n_samples == len(_FEATS)


def test_fit_sets_residual_gauges_in_registry():
    metrics.registry().reset()
    fit_profile(_planted_samples())
    gauges = metrics.snapshot()["gauges"]
    assert gauges["profile.fit_samples"] == len(_FEATS)
    assert gauges["profile.classes_flagged"] == 0.0
    assert gauges["profile.max_rel_residual"] == pytest.approx(0.0, abs=1e-9)
    for cls in ("einsum", "eltwise", "reshard"):
        assert gauges[f"profile.residual.{cls}"] == pytest.approx(1.0)


def test_fit_drops_outlier_and_still_recovers():
    samples = _planted_samples()
    bad = samples[0]
    samples[0] = dataclasses.replace(bad, measured_s=bad.measured_s * 100.0)
    prof = fit_profile(samples)
    assert prof.dropped >= 1
    assert_close(prof.params.peak_flops, PLANTED.peak_flops, kind="f32")


def test_fit_partial_features_keep_defaults():
    # compute-only samples: ici_bw / collective_launch_s stay defaults
    samples = [s for s in _planted_samples() if s.flops > 0.0]
    prof = fit_profile(samples)
    assert prof.fitted == ["peak_flops"]
    assert prof.params.ici_bw == DEFAULT_PARAMS.ici_bw
    assert prof.params.collective_launch_s == \
        DEFAULT_PARAMS.collective_launch_s
    assert_close(prof.params.peak_flops, PLANTED.peak_flops, kind="f32")


def test_fit_empty_and_degenerate_sample_sets():
    prof = fit_profile([])
    assert prof.params == DEFAULT_PARAMS and prof.fitted == []
    zeros = [StepSample("x", 0.0, 0.0, 0.0, 1.0)]
    assert fit_profile(zeros).fitted == []


# ---------------------------------------------------------------------------------
# persistence + resolution
# ---------------------------------------------------------------------------------


def test_roofline_params_json_roundtrip():
    d = PLANTED.as_dict()
    back = RooflineParams.from_dict(json.loads(json.dumps(d)))
    assert back == PLANTED
    assert back.digest() == PLANTED.digest()
    assert PLANTED.digest() != DEFAULT_PARAMS.digest()
    # unknown keys are ignored, missing keys default
    assert RooflineParams.from_dict({"bogus": 1.0}) == DEFAULT_PARAMS


def test_machine_profile_dump_load_roundtrip(tmp_path):
    prof = fit_profile(_planted_samples(), source="roundtrip")
    p = prof.dump(str(tmp_path / "prof.json"))
    back = MachineProfile.load(p)
    assert back.params == prof.params
    assert back.digest() == prof.digest()
    assert back.fitted == prof.fitted
    assert back.residuals == pytest.approx(prof.residuals)
    assert back.n_samples == prof.n_samples
    assert back.source == "roundtrip"


def test_resolve_profile_precedence(tmp_path, monkeypatch):
    prof = fit_profile(_planted_samples())
    path = prof.dump(str(tmp_path / "prof.json"))
    # nothing configured -> None (module defaults, bit-identical path)
    monkeypatch.delenv("REPRO_MACHINE_PROFILE", raising=False)
    assert resolve_profile(None) is None
    # explicit RooflineParams / MachineProfile / path all resolve
    assert resolve_profile(PLANTED) is PLANTED
    assert resolve_profile(prof) == prof.params
    assert resolve_profile(path) == prof.params
    # env fallback, cached by path+mtime, staleness gauge exported
    metrics.registry().reset()
    monkeypatch.setenv("REPRO_MACHINE_PROFILE", path)
    assert resolve_profile(None) == prof.params
    assert metrics.snapshot()["gauges"]["profile.staleness_s"] >= 0.0
    # explicit argument still wins over the env var
    assert resolve_profile(PLANTED) is PLANTED
    with pytest.raises(TypeError):
        resolve_profile(42)


# ---------------------------------------------------------------------------------
# re-scoring
# ---------------------------------------------------------------------------------


def test_rescore_improves_when_fitted_matches_machine():
    samples = _planted_samples()  # "machine" = PLANTED constants
    res = rescore_report(samples, PLANTED)
    assert res["in_band_classes"] == 3
    assert res["improved_all"]
    for row in res["classes"].values():
        assert row["ratio_fitted"] == pytest.approx(1.0)
        assert row["improved"]
    # defaults-vs-defaults: nothing gets strictly closer, so not improved
    res2 = rescore_report(samples, DEFAULT_PARAMS)
    assert not res2["improved_all"]


def test_rescore_empty_is_not_improved():
    assert not rescore_report([], PLANTED)["improved_all"]


# ---------------------------------------------------------------------------------
# calibration-report join
# ---------------------------------------------------------------------------------


def test_attach_profile_joins_residuals_into_report():
    events = [
        {"name": "m", "ph": "X", "ts": 0, "dur": 1.0,
         "pid": trace.MODELED_PID, "tid": 1, "args": {"class": "compute"}},
        {"name": "x", "ph": "X", "ts": 0, "dur": 2.0,
         "pid": trace.MEASURED_PID, "tid": 1,
         "args": {"class": "compute", "call": 0}},
    ]
    rep = calibrate.calibration_report(events)
    base_dict = rep.as_dict()
    assert "profile_digest" not in base_dict  # default path: dict unchanged
    assert all("fit_residual" not in r for r in base_dict["rows"])
    prof = MachineProfile(params=PLANTED, residuals={"compute": 1.2},
                          flagged=[])
    calibrate.attach_profile(rep, prof)
    d = rep.as_dict()
    assert d["profile_digest"] == PLANTED.digest()
    (row,) = [r for r in d["rows"] if r["class"] == "compute"]
    assert row["fit_residual"] == pytest.approx(1.2)
    assert row["fit_flagged"] is False


# ---------------------------------------------------------------------------------
# tight-timed traced execution + cache isolation (1-device harness mesh)
# ---------------------------------------------------------------------------------

m1 = Mesh.create((1, 1), ("x", "y"))


def _runner(trace_cfg=None, profile=None):
    from repro.core.partitioner import spmd_partition

    jmesh = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("x", "y"))

    def f(a, b):
        a = annotate(a, mesh_split(2, m1, ["x", -1]))
        b = annotate(b, mesh_split(2, m1, [-1, "y"]))
        return jnp.tanh(a @ b)

    return spmd_partition(f, jmesh, m1, trace=trace_cfg, profile=profile)


def test_tight_timing_matches_untraced_numerics_and_collects_samples():
    from repro.core.partitioner import clear_process_plan_cache

    clear_process_plan_cache()
    a = np.random.RandomState(0).randn(16, 16).astype(np.float32)
    b = np.random.RandomState(1).randn(16, 16).astype(np.float32)
    ref = np.asarray(_runner()(a, b))
    tight = _runner(obs.TraceConfig(timing="tight", repeats=2))
    out = np.asarray(tight(a, b))
    assert_close(out, ref, kind="exact")  # re-running pure steps is sound
    (entry,) = tight.plans.values()
    measured = tight.tracer.measured_events()
    assert len(measured) == len(entry.plan.steps)
    # synthetic-cursor timestamps: spans are schema-valid (no lane overlap)
    doc = tight.tracer.chrome_trace()
    assert trace.validate_trace_events(doc["traceEvents"]) == []
    samples = collect_samples(entry.plan, measured)
    assert len(samples) == len(measured)
    assert all(s.measured_s > 0.0 for s in samples)
    # the join reads features from the plan's own cost model
    assert any(s.flops > 0.0 for s in samples)


def test_cache_isolation_same_jaxpr_two_profiles(tmp_path):
    from repro.core import partitioner
    from repro.core.partitioner import (clear_process_plan_cache,
                                        process_plan_cache_stats)

    clear_process_plan_cache()
    obs.reset_control_events()
    a = np.ones((8, 8), np.float32)
    # profile-off: two call sites share one entry (bit-identical to the
    # pre-profile world: the pkey's trailing None is the same for both)
    _runner()(a, a)
    _runner()(a, a)
    assert process_plan_cache_stats().hits >= 1
    assert len(partitioner._PROCESS_CACHE) == 1
    # two distinct profiles: two *more* entries, no collision with default
    p2 = dataclasses.replace(PLANTED, peak_flops=PLANTED.peak_flops * 2)
    r1 = _runner(profile=PLANTED)
    r1(a, a)
    r2 = _runner(profile=p2)
    r2(a, a)
    assert len(partitioner._PROCESS_CACHE) == 3
    # the calibrated plans price with their own params
    (e1,) = r1.plans.values()
    assert e1.plan.params == PLANTED
    # applying a profile announces itself on the control lane
    applied = [e for e in obs.control_events()
               if e["name"] == "profile_applied"]
    assert len(applied) == 2
    assert applied[0]["args"]["digest"] == PLANTED.digest()
    clear_process_plan_cache()
    obs.reset_control_events()


def test_env_profile_changes_cache_key(tmp_path, monkeypatch):
    from repro.core import partitioner
    from repro.core.partitioner import clear_process_plan_cache

    prof = MachineProfile(params=PLANTED)
    path = prof.dump(str(tmp_path / "prof.json"))
    clear_process_plan_cache()
    a = np.ones((8, 8), np.float32)
    monkeypatch.delenv("REPRO_MACHINE_PROFILE", raising=False)
    _runner()(a, a)
    monkeypatch.setenv("REPRO_MACHINE_PROFILE", path)
    _runner()(a, a)  # ambient profile: distinct entry, same numerics
    assert len(partitioner._PROCESS_CACHE) == 2
    clear_process_plan_cache()


# ---------------------------------------------------------------------------------
# PlanCost default-path identity + calibrated pricing
# ---------------------------------------------------------------------------------


def _mlp_plan(params=None):
    mesh = Mesh.create((4, 8), ("x", "y"))

    def f(a, w):
        a = annotate(a, mesh_split(2, mesh, ["x", -1]))
        w = annotate(w, mesh_split(2, mesh, [-1, "y"]))
        return jnp.tanh(a @ w)

    closed = jax.make_jaxpr(f)(jax.ShapeDtypeStruct((64, 32), jnp.float32),
                               jax.ShapeDtypeStruct((32, 64), jnp.float32))
    prop = propagate(closed, mesh).result()
    return compile_plan(closed, prop, mesh, cost_only=True, profile=params)


def test_plancost_none_params_bit_identical_to_explicit_defaults():
    base = plan_cost(_mlp_plan(None))
    asdef = plan_cost(_mlp_plan(RooflineParams()))
    assert base.params is None
    assert base.total_s == asdef.total_s
    assert base.collective_s == asdef.collective_s
    assert base.compute_s == asdef.compute_s
    assert base.as_dict() == asdef.as_dict()


def test_plancost_calibrated_params_reprice():
    base = plan_cost(_mlp_plan(None))
    half = plan_cost(_mlp_plan(dataclasses.replace(
        DEFAULT_PARAMS, peak_flops=DEFAULT_PARAMS.peak_flops / 2.0,
        ici_bw=DEFAULT_PARAMS.ici_bw / 2.0)))
    assert half.total_s > base.total_s
    assert half.compute_s == pytest.approx(2.0 * base.compute_s)


# ---------------------------------------------------------------------------------
# memory telemetry
# ---------------------------------------------------------------------------------


class _FakePlan:
    peak_bytes = 1024.0


def test_memory_report_joins_or_degrades():
    rep = memory_report(_FakePlan(), None, None)
    assert rep["modeled_peak_bytes"] == 1024.0
    assert not rep["measured"] and rep["measured_peak_bytes"] is None
    rep2 = memory_report(_FakePlan(),
                         {"peak_bytes_in_use": 100.0},
                         {"peak_bytes_in_use": 900.0, "bytes_in_use": 500.0})
    assert rep2["measured"]
    assert rep2["measured_peak_bytes"] == 900.0
    assert rep2["measured_live_bytes"] == 500.0
    assert rep2["measured_peak_delta_bytes"] == 800.0
