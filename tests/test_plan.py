"""Partition-plan compilation + reshard planner unit tests (single device).

Pure-decision tests: the planner and the plan cache are exercised without any
collective execution (that lives in tests/multidev/test_reshard.py), so these
run in the default 1-device session.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.roofline import collective_wire_bytes
from repro.core import Mesh, annotate, mesh_split
from repro.core.collective_planner import (
    plan_reshard, simulate, _candidate_gather_all, _candidate_legacy,
)
from repro.core.compat import make_jax_mesh
from repro.core.einsum_rules import compile_einsum, plan_einsum

mesh = Mesh.create((2, 4), ("x", "y"))


# ---------------------------------------------------------------------------------
# reshard planner decisions
# ---------------------------------------------------------------------------------


def test_dim_move_uses_alltoall_at_fraction_of_allgather():
    """A mesh axis moving between dims must lower to AllToAll: (n-1)/n·B wire
    bytes instead of the greedy AllGather's (n-1)·B."""
    src = mesh_split(2, mesh, ["y", -1])
    dst = mesh_split(2, mesh, [-1, "y"])
    local = (2, 16)
    prog = plan_reshard(src, dst, local, dtype_bytes=4)
    assert [s.op for s in prog.steps] == ["all_to_all"]
    n = mesh.axis_size("y")
    bytes_local = 2 * 16 * 4
    assert prog.cost_bytes == collective_wire_bytes("all-to-all", n, bytes_local)
    # AllGather + DynamicSlice expression of the same move costs n× more
    gather = _candidate_gather_all(src, dst, local)
    gather_cost = simulate(src, dst, gather, local, 4)
    assert gather_cost == collective_wire_bytes("all-gather", n, bytes_local)
    assert prog.cost_bytes < gather_cost
    assert prog.cost_bytes == pytest.approx(gather_cost / n)


def test_slice_before_gather_ordering():
    """Slicing the target's new axis first shrinks every later gather."""
    src = mesh_split(2, mesh, ["x", -1])
    dst = mesh_split(2, mesh, [-1, "y"])
    local = (4, 16)
    prog = plan_reshard(src, dst, local, dtype_bytes=4)
    ops = [s.op for s in prog.steps]
    assert ops == ["dynamic_slice", "all_gather"], ops
    # legacy gathers first (256B on the wire), planner slices first (64B)
    legacy_cost = simulate(src, dst, _candidate_legacy(src, dst, local), local, 4)
    assert prog.cost_bytes < legacy_cost
    assert prog.cost_bytes == pytest.approx(legacy_cost / mesh.axis_size("y"))


def test_stacked_axes_gather_innermost_first():
    """Dropping the outer axis of a stacked dim must gather the inner one
    first (tiled collectives only operate on the innermost position)."""
    src = mesh_split(2, mesh, [("x", "y"), -1])
    dst = mesh_split(2, mesh, [-1, -1])
    prog = plan_reshard(src, dst, (1, 8), dtype_bytes=4)
    assert [(s.op, s.axis) for s in prog.steps] == [
        ("all_gather", "y"), ("all_gather", "x"),
    ]


def test_stacked_inner_axis_moves_via_alltoall():
    """d0=(x,y) -> d0=(x,), d1=(y,): the inner axis moves directly."""
    src = mesh_split(2, mesh, [("x", "y"), -1])
    dst = mesh_split(2, mesh, ["x", "y"])
    prog = plan_reshard(src, dst, (1, 8), dtype_bytes=4)
    assert [s.op for s in prog.steps] == ["all_to_all"]


def test_identity_reshard_is_free():
    s = mesh_split(2, mesh, ["x", "y"])
    prog = plan_reshard(s, s, (4, 2), dtype_bytes=4)
    assert prog.is_identity and prog.cost_bytes == 0.0


def test_planner_never_worse_than_legacy():
    """Over an exhaustive grid of (src, dst) sharding pairs the chosen program
    validates and never costs more than the greedy baseline."""
    opts = [(), ("x",), ("y",), ("x", "y")]
    shardings = []
    for d0 in opts:
        for d1 in opts:
            if set(d0) & set(d1):
                continue
            shardings.append(mesh_split(2, mesh, [d0 or -1, d1 or -1]))
    local_global = (8, 16)
    for src in shardings:
        for dst in shardings:
            local = tuple(
                g // src.num_shards(i) for i, g in enumerate(local_global)
            )
            prog = plan_reshard(src, dst, local, dtype_bytes=4)
            # simulate() revalidates and reprices the chosen steps
            assert simulate(src, dst, list(prog.steps), local, 4) == prog.cost_bytes
            legacy = _candidate_legacy(src, dst, local)
            if legacy is not None:
                assert prog.cost_bytes <= simulate(src, dst, legacy, local, 4) + 1e-9


# ---------------------------------------------------------------------------------
# einsum compilation
# ---------------------------------------------------------------------------------


def test_compile_einsum_reports_reduce_scatter():
    """Contracting-matched einsum whose requested output shards the psum axis
    must choose ReduceScatter and report it."""
    lhs = mesh_split(2, mesh, [-1, "y"])
    rhs = mesh_split(2, mesh, ["y", -1])
    out = mesh_split(2, mesh, ["y", -1])
    plan = compile_einsum("bd,df->bf", lhs, rhs, out, (8, 2), (2, 8))
    assert plan.compiled
    assert plan.scatter == (("y", 0),)
    assert plan.reduce_axes == ()
    assert any(c.startswith("reduce-scatter") for c in plan.collectives())
    # without a requested output it stays an AllReduce
    plan_ar = compile_einsum("bd,df->bf", lhs, rhs, None, (8, 2), (2, 8))
    assert plan_ar.reduce_axes == ("y",)
    assert any(c.startswith("all-reduce") for c in plan_ar.collectives())


def test_plan_einsum_one_sided_batch_dim_no_gather():
    """Satellite fix: an lhs-only batch sharding must not flag a rhs gather —
    the unsharded rhs is sliced (zero wire bytes), not gathered."""
    lhs = mesh_split(3, mesh, ["x", -1, -1])
    rhs = mesh_split(3, mesh, [-1, -1, -1])
    plan = plan_einsum("ebm,emh->ebh", lhs, rhs)
    assert plan.lhs_local.dims_mapping[0] == ("x",)
    assert plan.rhs_local.dims_mapping[0] == ("x",)
    compiled = compile_einsum("ebm,emh->ebh", lhs, rhs, None, (1, 4, 8), (2, 4, 8))
    assert compiled.rhs_program is not None
    assert [s.op for s in compiled.rhs_program.steps] == ["dynamic_slice"]
    assert compiled.rhs_program.cost_bytes == 0.0


# ---------------------------------------------------------------------------------
# plan cache: steady-state calls skip tracing + propagation entirely
# ---------------------------------------------------------------------------------


def test_plan_cache_zero_repropagation(monkeypatch):
    from repro.core import partitioner as pt

    jmesh = make_jax_mesh((1, 1), ("x", "y"))
    m = Mesh.create((1, 1), ("x", "y"))
    calls = {"propagate": 0, "trace": 0}
    real_propagate = pt.propagate
    real_make_jaxpr = jax.make_jaxpr

    def counting_propagate(*a, **kw):
        calls["propagate"] += 1
        return real_propagate(*a, **kw)

    def counting_make_jaxpr(*a, **kw):
        calls["trace"] += 1
        return real_make_jaxpr(*a, **kw)

    monkeypatch.setattr(pt, "propagate", counting_propagate)
    monkeypatch.setattr(pt.jax, "make_jaxpr", counting_make_jaxpr)

    def f(a, b):
        a = annotate(a, mesh_split(2, m, ["x", -1]))
        return jnp.tanh(a @ b)

    runner = pt.spmd_partition(f, jmesh, m)
    x = np.ones((4, 4), np.float32)
    y = np.ones((4, 4), np.float32)
    r1 = runner(x, y)
    assert calls == {"propagate": 1, "trace": 1}
    r2 = runner(x + 1, y)  # same avals -> cache hit, no re-trace/re-propagation
    assert calls == {"propagate": 1, "trace": 1}
    assert runner.cache_stats.hits == 1 and runner.cache_stats.misses == 1
    np.testing.assert_allclose(
        np.asarray(r2), np.tanh((x + 1) @ y), rtol=1e-6
    )
    runner(np.ones((8, 4), np.float32), y)  # new avals -> one more compile
    assert calls == {"propagate": 2, "trace": 2}
    assert runner.cache_stats.misses == 2


def test_plan_records_collective_stats():
    jmesh = make_jax_mesh((1, 1), ("x", "y"))
    m = Mesh.create((1, 1), ("x", "y"))

    def f(a, b):
        a = annotate(a, mesh_split(2, m, ["x", -1]))
        b = annotate(b, mesh_split(2, m, [-1, "y"]))
        return a @ b

    runner = __import__("repro.core.partitioner", fromlist=["spmd_partition"]).spmd_partition(
        f, jmesh, m
    )
    runner(np.ones((2, 2), np.float32), np.ones((2, 2), np.float32))
    (entry,) = runner.plans.values()
    stats = entry.plan.stats.as_dict()
    assert stats["eqns"] >= 3 and stats["steps"] >= 3
    assert isinstance(stats["collectives"], dict)


# ---------------------------------------------------------------------------------
# fallback partial gather (pure analysis)
# ---------------------------------------------------------------------------------


def test_fallback_keeps_unmodified_dims():
    from repro.core.plan import fallback_keep_sharding

    def f(a, b):
        return jax.lax.concatenate([a, b], 1)

    closed = jax.make_jaxpr(f)(
        jnp.ones((8, 4), jnp.float32), jnp.ones((8, 6), jnp.float32)
    )
    (eqn,) = [e for e in closed.jaxpr.eqns if e.primitive.name == "concatenate"]
    sh = mesh_split(2, mesh, ["y", "x"])
    keep = fallback_keep_sharding(eqn, [sh, sh], mesh)
    assert keep is not None
    kept, _ = keep
    # dim 0 sharding survives; the concat dim is gathered
    assert kept.dims_mapping == (("y",), ())
