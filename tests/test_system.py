"""End-to-end behaviour tests: train a reduced model to decreasing loss, then
serve from it; dry-run artifact sanity."""
import json
import os

import jax
import numpy as np
import pytest


def test_train_driver_end_to_end(tmp_path):
    from repro.launch.train import main

    losses = main([
        "--arch", "qwen1.5-0.5b", "--reduce", "16", "--steps", "12",
        "--batch", "4", "--seq", "64", "--ckpt-dir", str(tmp_path / "ck"),
        "--ckpt-every", "6",
    ])
    assert len(losses) == 12
    assert np.mean(losses[-3:]) < np.mean(losses[:3])
    # checkpoint written
    assert any(d.startswith("step_") for d in os.listdir(tmp_path / "ck"))


def test_serve_driver_end_to_end():
    from repro.launch.serve import main

    reqs = main(["--arch", "qwen1.5-0.5b", "--reduce", "32", "--slots", "2",
                 "--max-len", "32", "--new-tokens", "4", "--requests", "3"])
    assert all(len(r.out) == 4 for r in reqs)


def test_dryrun_single_cell_artifact(tmp_path):
    """The dry-run entry point works end-to-end in a subprocess (512 fake
    devices must not leak into this session)."""
    import subprocess
    import sys

    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = str(tmp_path)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen1.5-0.5b",
         "--shape", "decode_32k", "--single-pod-only", "--out", out],
        env=env, capture_output=True, text=True, timeout=1200,
    )
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    art = json.load(open(os.path.join(out, "qwen1.5-0.5b_decode_32k_pod16x16.json")))
    assert art["status"] == "ok"
    assert art["memory"]["peak_est_bytes"] < 16e9  # fits a v5e chip
    assert art["flops_per_dev"] > 0
    # this session still sees exactly 1 device
    assert len(jax.devices()) == 1
