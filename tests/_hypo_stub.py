"""Deterministic mini-`hypothesis` used when the real library is absent.

The container image does not ship `hypothesis` and installing packages is not
an option, so the property tests fall back to this: the same @given/@settings
surface, drawing a fixed number of pseudo-random examples from a seeded RNG.
Only the strategies the test-suite actually uses are implemented
(sampled_from, lists, integers).  No shrinking, no database — a failing
example prints its arguments and fails the test directly.
"""
from __future__ import annotations

import random


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def sampled_from(options):
    options = list(options)
    return _Strategy(lambda r: options[r.randrange(len(options))])


def integers(min_value, max_value):
    return _Strategy(lambda r: r.randint(min_value, max_value))


def lists(elements, min_size=0, max_size=10):
    return _Strategy(
        lambda r: [elements.draw(r) for _ in range(r.randint(min_size, max_size))]
    )


class strategies:
    sampled_from = staticmethod(sampled_from)
    integers = staticmethod(integers)
    lists = staticmethod(lists)


def settings(max_examples=25, deadline=None, **_kw):
    def deco(fn):
        fn._hypo_max_examples = max_examples
        return fn

    return deco


def given(*arg_strats, **kw_strats):
    def deco(fn):
        # NB: no functools.wraps — pytest would read the wrapped signature and
        # treat the strategy parameters as fixtures.
        def wrapper():
            n = min(getattr(fn, "_hypo_max_examples", 25), 50)
            rng = random.Random(0)
            for i in range(n):
                args = tuple(s.draw(rng) for s in arg_strats)
                kw = {k: s.draw(rng) for k, s in kw_strats.items()}
                try:
                    fn(*args, **kw)
                except Exception:
                    print(f"[hypo-stub] falsifying example #{i}: args={args} kw={kw}")
                    raise

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco
