"""Guarded execution: numerics sentinels, skip/rewind recovery, checkpoint
self-verification (single device).

The multidev drill (guarded TrainLoop surviving an injected NaN batch and a
K-consecutive-fault rewind on 8 fake devices) lives in
tests/multidev/test_guard_multidev.py; here the same machinery is exercised
on one device: the plan-lowered guard epilogue, the runner-side NumericsFault,
the in-jit skip select, the coordinator rewind path, and the offline
checkpoint verifier CLI.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(__file__))

from repro.configs.base import ModelConfig, get_strategy
from repro.core import Mesh, annotate
from repro.core.partitioner import spmd_partition
from repro.core.plan import (GuardConfig, NumericsFault, compile_plan,
                             guard_faults)
from repro.core.propagation import propagate
from repro.core.sharding import Sharding
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.train import checkpoint as ckpt
from repro.train.loop import (NumericFaultSpec, TrainConfig, TrainLoop,
                              guard_leaf_names)
from repro.train.optimizer import get_optimizer

st = get_strategy("2d_finalized")
TINY = ModelConfig(
    name="tiny", family="dense", num_layers=2, d_model=32, num_heads=4,
    num_kv_heads=4, d_ff=64, vocab_size=128, attn_chunk=16, remat="none",
)


def _pipe():
    return TokenPipeline(DataConfig(vocab_size=128, seq_len=8, global_batch=4,
                                    seed=1))


# ---------------------------------------------------------------------------------
# guard_faults decode + plan-lowered guard epilogue
# ---------------------------------------------------------------------------------


def test_guard_faults_decode():
    gc = GuardConfig(max_abs=10.0)
    stats = np.array([[0.0, 1.0],    # clean
                      [3.0, np.nan],  # non-finite
                      [0.0, 99.0]])   # abs-max breach
    faults = guard_faults(gc, stats, ("a", "b", "c"))
    kinds = {f["leaf"]: f["kind"] for f in faults}
    assert kinds == {"b": "nonfinite", "c": "absmax"}
    assert guard_faults(gc, np.array([[0.0, 1.0]]), ("a",)) == []


def test_append_guard_steps_structure():
    mesh = Mesh.create((1,), ("x",))
    closed = jax.make_jaxpr(lambda a, b: (jnp.tanh(a @ b), a + 1.0))(
        jax.ShapeDtypeStruct((8, 8), jnp.float32),
        jax.ShapeDtypeStruct((8, 8), jnp.float32))
    prop = propagate(closed, mesh).result()
    plan = compile_plan(closed, prop, mesh, optimize=False, guard=GuardConfig())
    gi = plan.guard
    assert gi is not None and gi.leaves == ("out[0]", "out[1]")
    # the sentinel vector is a first-class replicated output...
    assert len(plan.out_keys) == len(plan.out_shardings) == 3
    assert gi.out_index == 2
    # ...and its reduction is a first-class collective step, priced like any
    stat_ops = [s for s in plan.steps if s.op == "guard-stat"]
    pmaxes = [s for s in plan.steps
              if s.kind in ("collective", "fused") and s.reduce_op == "max"]
    assert len(stat_ops) == 2 and len(pmaxes) >= 1
    assert all(s.flops > 0 for s in stat_ops)


def test_spmd_partition_guard_raises():
    mesh = Mesh.create((1,), ("x",))
    jmesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("x",))

    def f(a, b):
        a = annotate(a, Sharding(mesh, (("x",), ())))
        c = jnp.tanh(a @ b)
        return c.sum(), c

    r = spmd_partition(f, jmesh, mesh, guard=GuardConfig())
    a = jnp.ones((8, 4), jnp.float32)
    b = jnp.ones((4, 8), jnp.float32)
    loss, c = r(a, b)  # clean call: guard vector stripped, outputs intact
    assert np.isfinite(float(loss)) and c.shape == (8, 8)
    with pytest.raises(NumericsFault) as ei:
        r(a.at[0, 0].set(jnp.nan), b)
    assert any(f["kind"] == "nonfinite" for f in ei.value.faults)


def test_guard_requires_compiled_plans():
    mesh = Mesh.create((1,), ("x",))
    jmesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("x",))
    with pytest.raises(ValueError):
        spmd_partition(lambda a: a, jmesh, mesh, compile_plans=False,
                       guard=GuardConfig())


# ---------------------------------------------------------------------------------
# train-step skip semantics + escalation
# ---------------------------------------------------------------------------------


def test_train_loop_skips_nan_batch(tmp_path):
    opt = get_optimizer("adafactor", lr=0.05)
    tc = TrainConfig(steps=10, ckpt_dir=str(tmp_path / "ck"), ckpt_every=4,
                     guard=GuardConfig(rewind_after=3),
                     numeric_fault=NumericFaultSpec(nan_at_step=4))
    events = []
    loop = TrainLoop(TINY, st, opt, tc, _pipe(),
                     hooks={"numerics_fault":
                            lambda s, f, c: events.append((s, c))})
    state, losses = loop.run()
    # the poisoned batch is dropped, the curve stays finite and continuous
    assert len(losses) == 9 and all(np.isfinite(losses))
    assert loop.skipped_steps == [4]
    assert loop.guard_counters == {"faults": 1, "skips": 1, "rewinds": 0}
    assert events == [(4, 1)]
    # params survived the poisoned step: the final state is finite
    for leaf in jax.tree_util.tree_leaves(state["params"]):
        assert bool(jnp.all(jnp.isfinite(leaf)))
    # counters ride in the manifest extra
    m = ckpt._load_manifest(str(tmp_path / "ck"),
                            ckpt.latest_step(str(tmp_path / "ck")))
    assert m["extra"]["guard"]["faults"] == 1


def test_train_loop_escalates_after_k_consecutive(tmp_path):
    opt = get_optimizer("adafactor", lr=0.05)
    tc = TrainConfig(steps=10, ckpt_dir=str(tmp_path / "ck"), ckpt_every=3,
                     guard=GuardConfig(rewind_after=3),
                     numeric_fault=NumericFaultSpec(nan_at_step=4, steps=5))
    loop = TrainLoop(TINY, st, opt, tc, _pipe())
    with pytest.raises(NumericsFault) as ei:
        loop.run()
    assert ei.value.consecutive == 3 and ei.value.step == 6
    assert loop.guard_counters["faults"] == 3
    assert loop.guard_counters["skips"] == 2


def test_grad_spike_caught_by_max_abs():
    opt = get_optimizer("adafactor", lr=0.05)
    tc = TrainConfig(steps=6, guard=GuardConfig(max_abs=1e6, rewind_after=99),
                     numeric_fault=NumericFaultSpec(grad_spike_at_step=3,
                                                    spike_factor=1e12))
    events = []
    loop = TrainLoop(TINY, st, opt, tc, _pipe(),
                     hooks={"numerics_fault":
                            lambda s, f, c: events.append((s, f))})
    _, losses = loop.run()
    assert len(losses) == 5 and all(np.isfinite(losses))
    (step, faults), = events
    assert step == 3 and any(f["kind"] == "absmax" for f in faults)


def test_guard_leaf_names_match_metrics_order():
    opt = get_optimizer("adafactor", lr=0.05)
    gc = GuardConfig(moments=True)
    tc = TrainConfig(steps=1, guard=gc)
    loop = TrainLoop(TINY, st, opt, tc, _pipe())
    state, _ = loop.run()
    names = guard_leaf_names(gc, state)
    assert names[0] == "loss"
    assert any(n.startswith("grads/") for n in names)
    assert any(n.startswith("opt/") for n in names)
    # one (nonfinite, absmax) pair per guarded leaf
    batch = {k: jnp.asarray(v) for k, v in _pipe().batch_at(0).items()}
    _, metrics = loop.step_fn(state, batch)
    assert metrics["guard"].shape == (2 * len(names),)


# ---------------------------------------------------------------------------------
# coordinator rewind drill (single device)
# ---------------------------------------------------------------------------------


def test_coordinator_rewinds_after_consecutive_faults(tmp_path):
    from repro.launch.elastic import ElasticCoordinator, FaultInjector

    opt = get_optimizer("adafactor", lr=0.05)
    tc = TrainConfig(steps=12, ckpt_dir=str(tmp_path / "ck"), ckpt_every=3,
                     guard=GuardConfig(rewind_after=2))
    inj = FaultInjector(nan_at_step=5, numeric_steps=4)
    coord = ElasticCoordinator(TINY, st, opt, tc, _pipe(), n_devices=1,
                               injector=inj, max_recoveries=2)
    state, losses = coord.run()
    # 12 steps, one skipped batch, zero process restarts
    assert len(losses) == 11 and all(np.isfinite(losses))
    (ev,) = [e for e in coord.recoveries if e.get("numerics")]
    assert ev["consecutive"] == 2 and ev["faults"]
    assert "rewound_to" in ev
    assert coord.loop.guard_counters["rewinds"] == 1
    # injection was disarmed on rewind: training completed
    assert tc.numeric_fault is None
    m = ckpt._load_manifest(str(tmp_path / "ck"),
                            ckpt.latest_step(str(tmp_path / "ck")))
    assert m["extra"]["guard"]["rewinds"] == 1


# ---------------------------------------------------------------------------------
# checkpoint manifest self-checksum + offline verify CLI
# ---------------------------------------------------------------------------------


def _save_two_steps(d):
    state = {"a": jnp.arange(6.0).reshape(2, 3),
             "n": {"b": jnp.ones(4, jnp.int32)}}
    ckpt.save(d, 5, state, extra={"data_cursor": 6})
    ckpt.save(d, 7, state)
    return state


def test_manifest_self_checksum_detects_edit(tmp_path):
    d = str(tmp_path / "ck")
    _save_two_steps(d)
    mp = os.path.join(d, "step_00000007", "manifest.json")
    m = json.load(open(mp))
    m["step"] = 999  # silent manifest edit
    json.dump(m, open(mp, "w"))
    with pytest.raises(ckpt.CheckpointCorruptError) as ei:
        ckpt._load_manifest(d, 7)
    assert "self-checksum" in str(ei.value)
    # restore (no pinned step) falls back to the intact step 5
    state = _save_two_steps(str(tmp_path / "ref"))
    out, manifest = ckpt.restore(d, state)
    assert manifest["step"] == 5
    assert manifest["restore_report"]["fell_back_from"] == [7]


def test_verify_cli(tmp_path):
    d = str(tmp_path / "ck")
    _save_two_steps(d)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    cmd = [sys.executable, "-m", "repro.train.checkpoint", "verify", d]
    r = subprocess.run(cmd, capture_output=True, text=True, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "step 5: ok" in r.stdout and "step 7: ok" in r.stdout
    # flip a byte in a leaf: CLI must fail and name the leaf
    p = os.path.join(d, "step_00000005", "a.npy")
    arr = np.load(p)
    arr[0, 0] += 1
    np.save(p, arr)
    r = subprocess.run(cmd, capture_output=True, text=True, env=env)
    assert r.returncode == 1
    assert "CORRUPT" in r.stdout and "leaf 'a'" in r.stdout
    # pinning the intact step still passes
    r = subprocess.run(cmd + ["--step", "7"], capture_output=True, text=True,
                       env=env)
    assert r.returncode == 0


def test_verify_dir_api(tmp_path):
    d = str(tmp_path / "ck")
    _save_two_steps(d)
    rep = ckpt.verify_dir(d)
    assert rep["ok"] and [r["step"] for r in rep["steps"]] == [5, 7]
    assert all(r["leaves"] == 2 for r in rep["steps"])
