"""One benchmark per paper table (GSPMD §5, Tables 1-8).

Each ``table*()`` returns rows ``(name, us_per_call, derived)``.  Wall-clock
entries are measured on CPU for the schedule/kernel benches; distributed
entries derive roofline terms from compiled dry-runs (this container has no
TPU — see EXPERIMENTS.md §Roofline for methodology).
"""
from __future__ import annotations

import math

import numpy as np

from .common import BENCH_ART, artifact, dryrun_cell, time_call


# --- Table 1: the three 2D sharding configurations -------------------------------
def table1_2d_sharding():
    """Paper Table 1/Figure 7: attempt1 vs attempt2 vs finalized on a dense
    model (paper dims M=8192 H=65536, depth-reduced for compile budget).
    Derived: per-device peak memory GB | wire GB (lower is better)."""
    rows = []
    overrides = {"d_model": 8192, "d_ff": 65536, "num_layers": 8,
                 "num_heads": 64, "num_kv_heads": 8, "vocab_size": 32000}
    for strat in ("2d_attempt1", "2d_attempt2", "2d_finalized"):
        rec = dryrun_cell("command-r-35b", "train_4k", strategy=strat,
                          overrides=overrides, tag=f"t1_{strat}")
        mem = rec["memory"]["peak_est_bytes"] / 1e9
        wire = rec["wire_bytes_per_dev"] / 1e9
        rows.append((f"table1/{strat}", 0.0, f"peak={mem:.2f}GB wire={wire:.2f}GB"))
    return rows


# --- Table 2: dense Transformer scaling -------------------------------------------
def table2_dense_scaling():
    """Paper Table 2: wide dense models at scale (we report roofline MFU for
    the assigned dense archs' train_4k cells; paper achieved 54-62%)."""
    from repro.analysis.roofline import terms_from_artifact

    rows = []
    for arch in ("qwen1.5-0.5b", "phi4-mini-3.8b", "command-r-35b",
                 "nemotron-4-340b"):
        rec = artifact(arch, "train_4k")
        if rec is None:
            continue
        t = terms_from_artifact(rec)
        rows.append((
            f"table2/{arch}", t.step_time_s * 1e6,
            f"mfu={t.mfu:.3f} dominant={t.dominant}",
        ))
    return rows


# --- Table 3: narrow vs wide communication share ----------------------------------
def table3_narrow():
    """Paper Table 3: narrow models are communication-bound on wide meshes."""
    from repro.analysis.roofline import terms_from_artifact

    rows = []
    for arch in ("qwen1.5-0.5b", "command-r-35b", "nemotron-4-340b"):
        rec = artifact(arch, "train_4k")
        if rec is None:
            continue
        t = terms_from_artifact(rec)
        share = t.collective_s / max(t.step_time_s, 1e-12)
        rows.append((
            f"table3/{arch}-d{rec['params']['total']:.0e}", 0.0,
            f"collective_share={share:.2f} (narrow models lose utilization)",
        ))
    return rows


# --- Table 4/5: pipeline schedules --------------------------------------------------
def _pipeline_bench(L, R, M):
    import jax
    import jax.numpy as jnp

    from repro.core.pipeline import pipeline

    D = 64
    rng = np.random.default_rng(0)
    ws = jnp.asarray(rng.standard_normal((L, R, D, D)).astype(np.float32) * 0.1)
    xs = jnp.asarray(rng.standard_normal((M, 4, D)).astype(np.float32))

    f = jax.jit(lambda w, x: pipeline(
        lambda wi, xi: jnp.tanh(xi @ wi), w, x, num_stages=L, num_rounds=R))
    f(ws, xs).block_until_ready()
    return time_call(lambda: f(ws, xs).block_until_ready(), iters=3)


def table4_pipeline():
    """Paper Table 4: pipeline stages (GPipe) — measured schedule cost on CPU
    (total stage-executions includes bubble padding, so wall time exposes the
    bubble exactly as the paper's Raw-FLOPS-vs-bubble accounting)."""
    from repro.core.pipeline import gpipe_bubble_ratio

    rows = []
    for L, M in ((2, 16), (4, 16), (8, 32)):
        us = _pipeline_bench(L, 1, M)
        rows.append((
            f"table4/gpipe_L{L}_M{M}", us,
            f"bubble={gpipe_bubble_ratio(L, M):.3f}",
        ))
    return rows


def table5_conformer():
    """Paper Table 5: GPipe vs circular schedule at the same microbatch count.
    Circular reaches the bubble ratio GPipe needs 4x the microbatches for."""
    from repro.core.pipeline import circular_bubble_ratio, gpipe_bubble_ratio

    rows = []
    L, M, R = 8, 16, 4
    us_g = _pipeline_bench(L, 1, M)
    us_c = _pipeline_bench(L, R, M)  # R rounds: 4x the layers, same devices
    rows.append((f"table5/gpipe_L{L}_M{M}", us_g,
                 f"bubble={gpipe_bubble_ratio(L, M):.3f}"))
    rows.append((f"table5/circular_L{L}_M{M}_R{R}", us_c,
                 f"bubble={circular_bubble_ratio(L, M, R):.3f}"))
    rows.append((f"table5/gpipe_L{L}_M{M*R}", _pipeline_bench(L, 1, M * R),
                 f"bubble={gpipe_bubble_ratio(L, M*R):.3f} (GPipe needs 4x M)"))
    return rows


# --- Table 6: sparse MoE scaling ----------------------------------------------------
def table6_moe():
    """Paper Table 6: MoE with AllToAll dispatch — a2a share of wire bytes."""
    rows = []
    for arch in ("granite-moe-1b-a400m", "llama4-maverick-400b-a17b"):
        rec = artifact(arch, "train_4k")
        if rec is None:
            continue
        c = rec["hlo_collectives_u1"]
        a2a = c["all-to-all"]["wire_bytes"] / max(c["wire_bytes"], 1)
        rows.append((
            f"table6/{arch}", 0.0,
            f"alltoall_share={a2a:.3f} of per-layer wire (paper: 2-11% of step)",
        ))
    return rows


# --- Table 7: hybrid sparse+dense ---------------------------------------------------
def table7_hybrid():
    from repro.analysis.roofline import terms_from_artifact

    rows = []
    rec = artifact("jamba-1.5-large-398b", "train_4k")
    if rec is not None:
        t = terms_from_artifact(rec)
        rows.append((
            "table7/jamba-1.5-large", t.step_time_s * 1e6,
            f"mfu={t.mfu:.3f} dominant={t.dominant} "
            f"(hybrid MoE: experts on X, H on Y)",
        ))
    return rows


# --- Table 8: spatial partitioning (3D U-Net) ---------------------------------------
def table8_spatial():
    """Paper Table 8: spatial partitioning of a 3D U-Net — halo-exchange conv
    numerics measured on 8 fake devices (subprocess), scaling derived."""
    import subprocess
    import sys
    import os

    from .common import ROOT

    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import time
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import STRATEGY_2D_FINALIZED as stf
import repro.configs.base as cb
import dataclasses
st = cb.Strategy("spatial", dict(stf.weight_rules),
                 {**stf.act_rules, "spatial": ("model",), "batch": ("data",)})
from repro.models import unet3d
from repro.models.layers import tree_init, is_param
import jax.tree_util as jtu
mesh = jax.make_mesh((1, 8), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
params_t = unet3d.param_tree(base=4, levels=2)
params = tree_init(params_t, jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 32, 16, 16), jnp.float32)
batch = {"image": x, "target": jnp.zeros((1, 1, 32, 16, 16))}
ref = unet3d.loss_fn(params, batch, None)
with jax.set_mesh(mesh):
    f = jax.jit(lambda p, b: unet3d.loss_fn(p, b, st))
    sharded = float(f(params, batch))
    txt = f.lower(params, batch).compile().as_text()
print("PARITY", abs(float(ref) - sharded))
print("CPERM", txt.count("collective-permute"))
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=1200)
    rows = []
    if proc.returncode == 0:
        parity = [l for l in proc.stdout.splitlines() if l.startswith("PARITY")]
        cperm = [l for l in proc.stdout.splitlines() if l.startswith("CPERM")]
        rows.append((
            "table8/unet3d_spatial8", 0.0,
            f"parity_err={float(parity[0].split()[1]):.2e} "
            f"halo_collective_permutes={cperm[0].split()[1]}",
        ))
    else:
        rows.append(("table8/unet3d_spatial8", 0.0,
                     f"FAILED: {proc.stderr[-200:]}"))
    return rows


# --- kernels microbench (not a paper table; supports §Perf) -------------------------
def kernels_micro():
    import jax.numpy as jnp
    from repro.kernels.ops import attention
    from repro.kernels.ref import attention_ref

    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 4, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 4, 256, 64)), jnp.float32)
    us_k = time_call(lambda: attention(q, k, k, causal=True).block_until_ready())
    us_r = time_call(lambda: attention_ref(q, k, k, causal=True).block_until_ready())
    return [
        ("kernels/flash_attention_interpret", us_k, "pallas interpret mode (CPU)"),
        ("kernels/attention_ref", us_r, "pure-jnp oracle"),
    ]


ALL_TABLES = [
    table1_2d_sharding,
    table2_dense_scaling,
    table3_narrow,
    table4_pipeline,
    table5_conformer,
    table6_moe,
    table7_hybrid,
    table8_spatial,
    kernels_micro,
]
