# One function per paper table. Print ``name,us_per_call,derived`` CSV.
#
#   python -m benchmarks.run            all paper tables
#   python -m benchmarks.run --smoke    plan-layer smoke only: planned-collective
#                                       counts + plan-cache hit rate, written to
#                                       artifacts/bench/BENCH_plan.json
import sys
import traceback


def main() -> None:
    smoke = "--smoke" in sys.argv[1:]
    smoke_rec = None
    if smoke:
        from . import plan_smoke

        smoke_rec = plan_smoke.smoke_record()
        tables = [lambda: plan_smoke.rows(smoke_rec)]
    else:
        from .tables import ALL_TABLES

        tables = ALL_TABLES

    print("name,us_per_call,derived")
    failures = 0
    for fn in tables:
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.1f},{derived}")
                sys.stdout.flush()
        except Exception as e:  # keep the harness going; report at the end
            failures += 1
            print(f"{fn.__name__},0.0,ERROR: {type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
    if smoke and not failures:
        from . import plan_smoke

        path = plan_smoke.write_artifact(smoke_rec)
        print(f"# artifact: {path}")
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
