# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import sys
import traceback


def main() -> None:
    from .tables import ALL_TABLES

    print("name,us_per_call,derived")
    failures = 0
    for fn in ALL_TABLES:
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.1f},{derived}")
                sys.stdout.flush()
        except Exception as e:  # keep the harness going; report at the end
            failures += 1
            print(f"{fn.__name__},0.0,ERROR: {type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
