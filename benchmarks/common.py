"""Shared benchmark helpers: timing, dry-run subprocess calls, artifact IO."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

HERE = os.path.dirname(__file__)
ROOT = os.path.abspath(os.path.join(HERE, ".."))
ART = os.path.join(ROOT, "artifacts", "dryrun")
BENCH_ART = os.path.join(ROOT, "artifacts", "bench")


def time_call(fn, *args, warmup: int = 1, iters: int = 3):
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def artifact(arch: str, shape: str, mesh: str = "pod16x16", tag: str = ""):
    key = f"{arch}_{shape}_{mesh}" + (f"_{tag}" if tag else "")
    path = os.path.join(ART, key + ".json")
    if os.path.exists(path):
        rec = json.load(open(path))
        if rec.get("status") == "ok":
            return rec
    return None


def dryrun_cell(arch: str, shape: str, *, strategy=None, overrides=None,
                tag: str = "", out_dir: str = None, multi_pod: bool = False,
                force: bool = False):
    """Compile one cell in a subprocess (512 fake devices) and return the
    artifact record.  Cached by tag."""
    out_dir = out_dir or BENCH_ART
    os.makedirs(out_dir, exist_ok=True)
    mesh = "pod2x16x16" if multi_pod else "pod16x16"
    key = f"{arch}_{shape}_{mesh}" + (f"_{tag}" if tag else "")
    path = os.path.join(out_dir, key + ".json")
    if not force and os.path.exists(path):
        rec = json.load(open(path))
        if rec.get("status") in ("ok", "skipped"):
            return rec
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
from repro.launch.dryrun import run_cell
rec = run_cell({arch!r}, {shape!r}, multi_pod={multi_pod!r}, out_dir={out_dir!r},
               strategy={strategy!r}, cfg_overrides={overrides!r}, tag={tag!r},
               verbose=False)
print("STATUS", rec["status"])
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=3000)
    if proc.returncode != 0:
        raise RuntimeError(f"dryrun {key} failed:\n{proc.stdout[-1500:]}\n{proc.stderr[-1500:]}")
    return json.load(open(path))


def roofline_row(rec):
    from repro.analysis.roofline import terms_from_artifact

    t = terms_from_artifact(rec)
    return t
