"""Plan-benchmark regression guard: fresh smoke vs the committed baseline.

    PYTHONPATH=src python -m benchmarks.guard   (or ``make bench-guard``)

Recomputes ``plan_smoke.smoke_record()`` in memory and diffs it against the
committed ``artifacts/bench/BENCH_plan.json``.  Fails (exit 1) when any cell
regresses:

* reshard/einsum cells — planned wire bytes grow, the planned collective
  sequence gets longer, or the lattice-vs-PR1 ratio exceeds 1.0 (the search
  must never lose to the greedy planner it refines);
* optimizer cells — post-pass wire bytes or collective-launch counts grow,
  the pass pipeline stops strictly improving a cell it used to improve, or a
  cell loses its fused buckets;
* inline cells (whole-program passes) — post-pass whole-program wire bytes,
  launch counts, or in-body reshard counts grow; inlined-body / hoisted-
  reshard / fused-bucket counts drop; or the modeled overlap ratio regresses;
* autoshard cells — the search stops finding a feasible assignment, the
  searched modeled cost exceeds the hand-annotated baseline or regresses vs
  the committed record, or the assignment breaks its memory budget;
* pipeline cells (§3.3 stage-stacked pipelining) — no pipeline decision is
  feasible any more, the searched stage count loses to the handpicked one,
  the bubble fraction drifts from (S−1)/(M+S−1), modeled ppermute bytes or
  the pipelined cost regress, or a cell where pipelining matched/beat (or
  uniquely fit the memory budget vs) pure tensor stops doing so;
* elastic cells (fault-tolerant recovery) — the modeled mesh-shrink restore
  program regresses (wire bytes, launches, reshard seconds, or the
  gather-all ratio), or the warm-started autoshard re-solve stops being
  feasible / stops taking strictly fewer cost lowerings than the cold solve;
* guard cells (numerics sentinels) — the guard epilogue's modeled overhead
  exceeds its hard 1%-of-total_s cap, regresses vs the committed record, or
  the epilogue stops emitting its steps/collective;
* profile cells (machine-profile calibration) — the fitter stops recovering
  planted constants on the synthetic cell, the end-to-end loop stops
  improving every in-band calibration ratio (or the profile-off path stops
  hitting the process plan cache / distinct profiles stop keeping distinct
  entries), or the calibrated qwen re-score stops changing total_s or loses
  to the hand-annotated baseline;
* verifier telemetry — the bench run stops verifying plans, or a committed
  record carries static-verifier violations (want exactly 0);
* lattice telemetry — a reshard in the benchmark set starts hitting the
  node/depth caps of the branch-and-bound search;
* cache cells — the per-runner or process-level hit rate drops.

Timing fields (``build_*_ms``) are informational and never guarded.  New
cells in the fresh record are reported but pass (the baseline learns them on
the next artifact commit); cells *missing* from the fresh record fail.  On
success the fresh record is written back as the artifact, so ``make check``
computes the smoke record exactly once (``make bench-smoke`` remains the
unconditional, comparison-free refresh).
"""
from __future__ import annotations

import json
import os
import sys

from .common import BENCH_ART

BASELINE = os.path.join(BENCH_ART, "BENCH_plan.json")
_EPS = 1e-6  # float-compare slack on byte counts


def _fail(msgs, msg):
    msgs.append("REGRESSION: " + msg)


def _launches(cell):
    # DynamicSlice is local addressing, not a collective launch (same
    # convention as plan_opt.count_collective_launches)
    return sum(1 for c in cell["planned"] if not c.startswith("dynamic-slice"))


def _check_reshard_cell(msgs, name, base, fresh):
    if fresh["planned_bytes"] > base["planned_bytes"] * (1 + _EPS):
        _fail(msgs, f"{name}: planned_bytes {base['planned_bytes']:.3e} -> "
                    f"{fresh['planned_bytes']:.3e}")
    # more launches is only a regression when the bytes didn't improve —
    # a cheaper program with extra (free or amortized) steps is a win, and
    # exactly what the lattice search produces
    if (_launches(fresh) > _launches(base)
            and fresh["planned_bytes"] >= base["planned_bytes"] * (1 - _EPS)):
        _fail(msgs, f"{name}: collective launches {_launches(base)} -> "
                    f"{_launches(fresh)} without a byte improvement")
    if fresh.get("ratio_vs_pr1", 1.0) > 1.0 + _EPS:
        _fail(msgs, f"{name}: lattice worse than PR1 planner "
                    f"(ratio {fresh['ratio_vs_pr1']:.3f} > 1.0)")


def _check_opt_cell(msgs, name, base, fresh):
    for k in ("wire_bytes_after", "collectives_after"):
        if fresh[k] > base[k] * (1 + _EPS):
            _fail(msgs, f"{name}: {k} {base[k]} -> {fresh[k]}")
    # cells the pipeline used to strictly improve must stay strictly improved
    if base["wire_bytes_after"] < base["wire_bytes_before"] * (1 - _EPS):
        if not fresh["wire_bytes_after"] < fresh["wire_bytes_before"] * (1 - _EPS):
            _fail(msgs, f"{name}: pass pipeline no longer reduces wire bytes")
    if base["collectives_after"] < base["collectives_before"]:
        if not fresh["collectives_after"] < fresh["collectives_before"]:
            _fail(msgs, f"{name}: pass pipeline no longer reduces collective count")
    if fresh["fused_buckets"] < base["fused_buckets"]:
        _fail(msgs, f"{name}: fused buckets {base['fused_buckets']} -> "
                    f"{fresh['fused_buckets']}")


def _check_inline_cell(msgs, name, base, fresh):
    """Whole-program cells: inlining/hoisting wins and the overlap model.

    ``overlap`` detail and raw second-totals are informational; the guarded
    surface is the whole-program bytes/launches the passes remove, the
    structural counters (bodies inlined, reshards hoisted, reshards left in
    bodies, fused buckets), and the modeled overlap ratio."""
    for k in ("whole_wire_bytes_after", "whole_launches_after",
              "inner_reshards_after"):
        if fresh[k] > base[k] * (1 + _EPS):
            _fail(msgs, f"{name}: {k} {base[k]} -> {fresh[k]}")
    for k in ("inlined_bodies", "hoisted_reshards", "fused_buckets"):
        if fresh[k] < base[k]:
            _fail(msgs, f"{name}: {k} {base[k]} -> {fresh[k]}")
    # cells the passes used to strictly improve must stay strictly improved
    if base["whole_wire_bytes_after"] < base["whole_wire_bytes_before"] * (1 - _EPS):
        if not (fresh["whole_wire_bytes_after"]
                < fresh["whole_wire_bytes_before"] * (1 - _EPS)):
            _fail(msgs, f"{name}: passes no longer reduce whole-program wire bytes")
    if base["whole_launches_after"] < base["whole_launches_before"]:
        if not fresh["whole_launches_after"] < fresh["whole_launches_before"]:
            _fail(msgs, f"{name}: passes no longer reduce whole-program launches")
    if fresh["overlap_ratio"] > base["overlap_ratio"] * (1 + _EPS):
        _fail(msgs, f"{name}: overlap_ratio {base['overlap_ratio']:.4f} -> "
                    f"{fresh['overlap_ratio']:.4f}")


def _check_autoshard_cell(msgs, name, base, fresh):
    if not fresh.get("feasible", False):
        # infeasible cells carry null metrics (strict JSON) — nothing else
        # to compare, the cell already failed
        _fail(msgs, f"{name}: search found no feasible assignment")
        return
    if not fresh.get("baseline_feasible", False):
        _fail(msgs, f"{name}: hand-annotated baseline no longer fits its budget")
        return
    # the searched assignment must never cost more than the hand-annotated
    # baseline (the baseline is a valid search point), nor regress vs the
    # committed record (the search is deterministic under the fixed seed)
    if fresh["ratio_vs_baseline"] > 1.0 + _EPS:
        _fail(msgs, f"{name}: searched cost exceeds hand-annotated baseline "
                    f"(ratio {fresh['ratio_vs_baseline']:.3f})")
    if base.get("searched_total_s") is not None and (
            fresh["searched_total_s"] > base["searched_total_s"] * (1 + _EPS)):
        _fail(msgs, f"{name}: searched_total_s {base['searched_total_s']:.3e} "
                    f"-> {fresh['searched_total_s']:.3e}")
    if fresh["searched_peak_bytes"] > fresh["budget_bytes"] * (1 + _EPS):
        _fail(msgs, f"{name}: searched peak {fresh['searched_peak_bytes']:.3e}B "
                    f"over budget {fresh['budget_bytes']:.3e}B")


def _check_elastic_cell(msgs, name, base, fresh):
    """Elastic-recovery cells (launch/elastic.py).

    Reshard cells: the modeled restore program must not regress — wire
    bytes, collective launches, or modeled reshard seconds grow, the program
    loses to the gather-all reference, or leaves stop being resharded.
    Warm-solve cells: the warm start must stay feasible and keep performing
    strictly fewer cost lowerings than the cold solve, at no worse modeled
    cost.  ``search_ms_*`` are wall-clock and never guarded."""
    if "reshard_s" in fresh:
        for k in ("wire_bytes", "launches", "reshard_s"):
            if fresh[k] > base[k] * (1 + _EPS):
                _fail(msgs, f"{name}: {k} {base[k]:.3e} -> {fresh[k]:.3e}")
        if fresh["ratio_vs_gather_all"] > 1.0 + _EPS:
            _fail(msgs, f"{name}: reshard program worse than gather-all "
                        f"(ratio {fresh['ratio_vs_gather_all']:.3f} > 1.0)")
        if fresh["resharded_leaves"] < base["resharded_leaves"]:
            _fail(msgs, f"{name}: resharded leaves "
                        f"{base['resharded_leaves']} -> "
                        f"{fresh['resharded_leaves']}")
        return
    if not fresh.get("warm_feasible", False):
        _fail(msgs, f"{name}: warm re-solve no longer feasible")
        return
    if not fresh.get("warm_started", False):
        _fail(msgs, f"{name}: warm point no longer seeds the search")
    if fresh["evals_warm"] >= fresh["evals_cold"]:
        _fail(msgs, f"{name}: warm solve evals {fresh['evals_warm']} not "
                    f"fewer than cold {fresh['evals_cold']}")
    if fresh["evals_warm"] > base["evals_warm"]:
        _fail(msgs, f"{name}: evals_warm {base['evals_warm']} -> "
                    f"{fresh['evals_warm']}")
    if fresh["ratio_warm_vs_cold"] > 1.0 + _EPS:
        _fail(msgs, f"{name}: warm-started cost exceeds cold solve "
                    f"(ratio {fresh['ratio_warm_vs_cold']:.3f})")


def _check_pipeline_cell(msgs, name, base, fresh):
    """§3.3 pipeline cells: the searched stage count must never lose to the
    handpicked reference (it is a point in the decision space), the bubble
    must match its closed form (S−1)/(M+S−1), the modeled ppermute traffic
    and pipeline cost must not regress, and a cell where pipelining beat (or
    was the only fit for) pure tensor must stay that way."""
    if base.get("pipeline_feasible") and not fresh.get("pipeline_feasible"):
        _fail(msgs, f"{name}: no pipeline decision is feasible any more")
        return
    if not fresh.get("pipeline_feasible"):
        return
    if fresh["ratio_vs_handpicked"] > 1.0 + _EPS:
        _fail(msgs, f"{name}: searched stage count worse than handpicked "
                    f"(ratio {fresh['ratio_vs_handpicked']:.3f} > 1.0)")
    dec = fresh["chosen"]
    want_bubble = (dec["num_stages"] - 1) / (
        dec["num_microbatches"] + dec["num_stages"] - 1)
    if abs(fresh["bubble_fraction"] - want_bubble) > _EPS:
        _fail(msgs, f"{name}: bubble {fresh['bubble_fraction']:.4f} != "
                    f"closed form {want_bubble:.4f}")
    if base.get("pipeline_feasible"):
        for k in ("pipeline_total_s", "ppermute_bytes"):
            if base.get(k) is not None and fresh[k] > base[k] * (1 + _EPS):
                _fail(msgs, f"{name}: {k} {base[k]:.3e} -> {fresh[k]:.3e}")
        if base.get("pipeline_chosen") and not fresh.get("pipeline_chosen"):
            _fail(msgs, f"{name}: pipelining no longer at or below the best "
                        f"pure-tensor assignment")
        if base.get("mixed") and not fresh.get("mixed"):
            _fail(msgs, f"{name}: chosen assignment no longer mixes pipeline "
                        f"and tensor axes")


def _check_guard_cell(msgs, name, base, fresh):
    """Guarded-execution cells: the numerics-sentinel epilogue's modeled
    overhead must stay under its hard cap (≤ 1% of the unguarded total_s)
    and must not regress vs the committed record; the epilogue must keep
    emitting its steps (a zero-step guard means the sentinels silently
    vanished from the lowering)."""
    cap = fresh.get("overhead_cap")
    if cap is not None and fresh["overhead_ratio"] > cap + _EPS:
        _fail(msgs, f"{name}: sentinel overhead {fresh['overhead_ratio']*100:.3f}% "
                    f"over the {cap*100:.0f}% cap")
    if fresh["overhead_ratio"] > base["overhead_ratio"] * (1 + 0.25) + _EPS:
        _fail(msgs, f"{name}: overhead_ratio {base['overhead_ratio']:.2e} -> "
                    f"{fresh['overhead_ratio']:.2e}")
    if fresh["guard_steps"] <= 0:
        _fail(msgs, f"{name}: guard epilogue emits no steps "
                    f"({fresh['guard_steps']})")
    if fresh["guard_launches"] <= 0:
        _fail(msgs, f"{name}: guard reduction no longer a collective launch")


def _check_obs_cell(msgs, name, base, fresh):
    """Observability cells: trace exports must stay schema-valid, the
    modeled timeline must replay to exactly the overlap schedule's makespan,
    tracing overhead (off-path cache identity on the exec cell, plan-cost
    identity on the qwen cell) must stay under its hard cap, and the
    calibration table must keep a ratio for every priced step class.
    Timing fields (export_ms/exec_ms) and calibration ratios are
    informational — never compared."""
    if not fresh.get("schema_ok"):
        _fail(msgs, f"{name}: exported trace fails schema validation "
                    f"({fresh.get('schema_problems', '?')} problem(s))")
    cap = fresh.get("overhead_cap")
    if cap is not None and fresh["overhead_ratio"] > cap + _EPS:
        _fail(msgs, f"{name}: tracing overhead "
                    f"{fresh['overhead_ratio']*100:.3f}% over the "
                    f"{cap*100:.0f}% cap")
    if "makespan_matches_schedule" in fresh:
        if not fresh["makespan_matches_schedule"]:
            _fail(msgs, f"{name}: modeled timeline makespan "
                        f"{fresh['modeled_makespan_s']:.3e}s diverged from "
                        f"the overlap schedule "
                        f"{fresh['schedule_overlapped_s']:.3e}s")
        if fresh.get("steps", 0) <= 0:
            _fail(msgs, f"{name}: modeled timeline is empty")
    if "calibration_complete" in fresh:
        if not fresh["calibration_complete"]:
            _fail(msgs, f"{name}: calibration table incomplete (a priced "
                        f"step class has no measured/modeled ratio)")
        if fresh.get("measured_events", 0) <= 0:
            _fail(msgs, f"{name}: traced execution recorded no measured "
                        f"spans")
        if fresh.get("modeled_events", 0) <= 0:
            _fail(msgs, f"{name}: no modeled lane in the traced runner")
        if not fresh.get("off_process_cache_hit"):
            _fail(msgs, f"{name}: TraceConfig(enabled=False) runner missed "
                        f"the process plan cache (disabled tracing is no "
                        f"longer free)")


def _check_chaos_cell(msgs, name, base, fresh):
    """Chaos-soak cell (launch/chaos.py): the seeded campaign must hold
    every invariant (zero violations), fire and recover from every injected
    event in a single restore pass, keep every mesh-changing re-solve
    warm-started, and keep the warm evals strictly under the cold solve on
    the final mesh.  ``recovery_ms_*`` are wall-clock — never guarded."""
    if not fresh.get("ok"):
        _fail(msgs, f"{name}: soak violated invariants: "
                    f"{fresh.get('violations')}")
    if fresh.get("recoveries", 0) < base.get("recoveries", 0):
        _fail(msgs, f"{name}: recoveries {base['recoveries']} -> "
                    f"{fresh['recoveries']} (an injected event stopped "
                    f"triggering recovery)")
    if fresh.get("restores") != fresh.get("recoveries"):
        _fail(msgs, f"{name}: {fresh.get('restores')} restores for "
                    f"{fresh.get('recoveries')} recoveries (want exactly "
                    f"one restore pass each)")
    if not fresh.get("single_pass"):
        _fail(msgs, f"{name}: a recovery episode restored more than once")
    if not fresh.get("warm_started_all"):
        _fail(msgs, f"{name}: a mesh-changing re-solve ran cold")
    if fresh.get("evals_warm_max", 0) >= fresh.get("evals_cold", 0):
        _fail(msgs, f"{name}: warm evals {fresh.get('evals_warm_max')} not "
                    f"fewer than cold {fresh.get('evals_cold')}")
    if fresh.get("losses", 0) < fresh.get("steps", 0):
        _fail(msgs, f"{name}: loss curve has {fresh.get('losses')} points "
                    f"for {fresh.get('steps')} steps (not continuous)")


def _check_profile_cell(msgs, name, base, fresh):
    """Machine-profile cells (repro.obs.profile): the synthetic fit must
    keep recovering its planted constants exactly, the end-to-end loop must
    keep improving every in-band class's calibration ratio (with the
    profile-off path still hitting the process plan cache and distinct
    profiles keeping distinct entries), and the calibrated qwen re-score
    must keep changing total_s without the searched assignment losing to
    the hand-annotated baseline.  Fitted constants, residual ratios, and
    ``search_ms`` are host-specific — never compared."""
    if "recovered" in fresh:
        if not fresh["recovered"]:
            _fail(msgs, f"{name}: fitter no longer recovers planted "
                        f"constants (max_rel_err "
                        f"{fresh.get('max_rel_err'):.3g})")
        if fresh.get("flagged"):
            _fail(msgs, f"{name}: exact synthetic fit flagged classes "
                        f"{fresh['flagged']} (want none)")
        return
    if "improved_all" in fresh:
        if fresh.get("n_samples", 0) <= 0:
            _fail(msgs, f"{name}: tight-timed run produced no samples")
        if fresh.get("in_band_classes", 0) <= 0:
            _fail(msgs, f"{name}: no in-band step class to calibrate")
        if not fresh["improved_all"]:
            _fail(msgs, f"{name}: fitted profile no longer brings every "
                        f"in-band class's ratio closer to 1.0 than the "
                        f"defaults")
        if not fresh.get("off_cache_hit"):
            _fail(msgs, f"{name}: profile-off build missed the process "
                        f"plan cache (unset REPRO_MACHINE_PROFILE is no "
                        f"longer bit-identical)")
        if not fresh.get("isolation_ok"):
            _fail(msgs, f"{name}: distinct profiles no longer keep "
                        f"distinct plan-cache entries "
                        f"({fresh.get('isolation_entries')} entries)")
        if fresh.get("profile_applied_events", 0) < 2:
            _fail(msgs, f"{name}: profile_applied control events "
                        f"{fresh.get('profile_applied_events')} < 2")
        return
    if not fresh.get("feasible", False):
        _fail(msgs, f"{name}: calibrated search found no feasible assignment")
        return
    if not fresh.get("total_s_changed"):
        _fail(msgs, f"{name}: calibrated profile no longer changes total_s "
                    f"(feedback path severed)")
    if fresh["ratio_vs_baseline"] > 1.0 + _EPS:
        _fail(msgs, f"{name}: calibrated searched cost exceeds baseline "
                    f"(ratio {fresh['ratio_vs_baseline']:.3f})")
    if base.get("profiled_total_s") is not None and (
            fresh["profiled_total_s"] > base["profiled_total_s"] * (1 + _EPS)):
        _fail(msgs, f"{name}: profiled_total_s {base['profiled_total_s']:.3e} "
                    f"-> {fresh['profiled_total_s']:.3e}")


def _check_metrics(msgs, base, fresh):
    """Unified metrics snapshot: the record must join every pre-existing
    telemetry surface (the PR 8 acceptance bar — cache hit rates, verifier
    violations, lattice counters readable from one snapshot) and the bench
    run must have fed the autoshard instruments."""
    mx = fresh.get("metrics")
    if mx is None:
        if base.get("metrics") is not None:
            _fail(msgs, "metrics: snapshot missing from fresh run")
        return
    sources = mx.get("sources", {})
    for want in ("lattice", "plan_verify", "process_plan_cache"):
        if want not in sources:
            _fail(msgs, f"metrics: source '{want}' missing from snapshot")
        elif "error" in sources[want]:
            _fail(msgs, f"metrics: source '{want}' errored: "
                        f"{sources[want]['error']}")
    counters = mx.get("counters", {})
    if counters.get("autoshard.evals", 0) <= 0:
        _fail(msgs, "metrics: autoshard.evals counter never incremented")
    if mx.get("histograms", {}).get("autoshard.eval_ms", {}).get(
            "count", 0) <= 0:
        _fail(msgs, "metrics: autoshard.eval_ms histogram is empty")


def _check_plan_verify(msgs, base, fresh):
    """Verifier telemetry: every bench lowering runs through the static plan
    verifier (plans_verified > 0) and a committed record must be violation-
    free (violations raise in strict mode, so > 0 here means someone ran
    with strict=False and shipped a bad plan)."""
    pv = fresh.get("plan_verify")
    if pv is None:
        if base.get("plan_verify") is not None:
            _fail(msgs, "plan_verify: telemetry section missing from fresh run")
        return
    if pv.get("plans_verified", 0) <= 0:
        _fail(msgs, "plan_verify: no plans were verified during the bench run")
    if pv.get("violations", 0) > 0:
        _fail(msgs, f"plan_verify: {pv['violations']} violation(s) in a "
                    f"committed record (want 0)")


def _check_lattice(msgs, base, fresh):
    b = base.get("lattice_telemetry")
    f = fresh.get("lattice_telemetry")
    if not b or not f:
        return
    # the ROADMAP claim: no reshard in the benchmark grid hits the search
    # caps — hard zero over "cells"; the totals (incl. model-sized autoshard
    # lowering, where depth-cap prunes are the bound working) only guard
    # against regression vs the committed record
    fc = f.get("cells", {})
    for k in ("node_cap_hits", "depth_cap_hits"):
        if fc.get(k, 0) > 0:
            _fail(msgs, f"lattice_telemetry: reshard grid {k} = {fc[k]} (want 0)")
    bt, ft = b.get("total", {}), f.get("total", {})
    for k in ("node_cap_hits", "depth_cap_hits"):
        if ft.get(k, 0) > bt.get(k, 0):
            _fail(msgs, f"lattice_telemetry: total {k} "
                        f"{bt.get(k, 0)} -> {ft.get(k, 0)}")
    if fc.get("searches", 0) == 0 < b.get("cells", {}).get("searches", 0):
        _fail(msgs, "lattice_telemetry: lattice search no longer runs")


def _check_cache(msgs, key, base, fresh):
    b, f = base.get(key, {}), fresh.get(key, {})
    if b and f and f["hit_rate"] < b["hit_rate"] - _EPS:
        _fail(msgs, f"{key}: hit rate {b['hit_rate']:.2f} -> {f['hit_rate']:.2f}")


def compare(base: dict, fresh: dict):
    """Return (failure messages, info messages)."""
    msgs, info = [], []
    for kind, checker in (("cells", _check_reshard_cell),
                          ("opt_cells", _check_opt_cell),
                          ("inline_cells", _check_inline_cell),
                          ("autoshard_cells", _check_autoshard_cell),
                          ("pipeline_cells", _check_pipeline_cell),
                          ("elastic_cells", _check_elastic_cell),
                          ("guard_cells", _check_guard_cell),
                          ("obs_cells", _check_obs_cell),
                          ("chaos_cells", _check_chaos_cell),
                          ("profile_cells", _check_profile_cell)):
        base_cells = {c["name"]: c for c in base.get(kind, [])}
        fresh_cells = {c["name"]: c for c in fresh.get(kind, [])}
        for name, bc in base_cells.items():
            fc = fresh_cells.get(name)
            if fc is None:
                _fail(msgs, f"{name}: cell missing from fresh run")
                continue
            checker(msgs, name, bc, fc)
        for name in fresh_cells:
            if name not in base_cells:
                info.append(f"new cell (not in baseline): {name}")
    _check_cache(msgs, "plan_cache", base, fresh)
    _check_cache(msgs, "process_plan_cache", base, fresh)
    _check_lattice(msgs, base, fresh)
    _check_plan_verify(msgs, base, fresh)
    _check_metrics(msgs, base, fresh)
    return msgs, info


def main() -> int:
    if not os.path.exists(BASELINE):
        print(f"bench-guard: no baseline at {BASELINE}; "
              "run `make bench-smoke` and commit the artifact first")
        return 1
    base = json.load(open(BASELINE))
    from . import plan_smoke

    fresh = plan_smoke.smoke_record()
    msgs, info = compare(base, fresh)
    for m in info:
        print(f"bench-guard: {m}")
    if msgs:
        for m in msgs:
            print(f"bench-guard: {m}", file=sys.stderr)
        print(f"bench-guard: FAILED ({len(msgs)} regression(s) vs {BASELINE})",
              file=sys.stderr)
        return 1
    ncells = (len(base.get("cells", [])) + len(base.get("opt_cells", []))
              + len(base.get("inline_cells", []))
              + len(base.get("autoshard_cells", []))
              + len(base.get("pipeline_cells", []))
              + len(base.get("elastic_cells", []))
              + len(base.get("guard_cells", []))
              + len(base.get("obs_cells", []))
              + len(base.get("profile_cells", [])))
    path = plan_smoke.write_artifact(fresh)
    print(f"bench-guard: OK ({ncells} cells, no regressions vs committed baseline)")
    print(f"# artifact refreshed: {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
