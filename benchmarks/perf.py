"""§Perf hillclimb driver: compile tagged variants of the three chosen cells
and print the roofline-term deltas.

    PYTHONPATH=src python -m benchmarks.perf qwen1.5-0.5b train_4k \
        --tag fsdp --strategy fsdp_1d --overrides '{"xent_chunk": 512}'
"""
from __future__ import annotations

import argparse
import json
import os

from .common import BENCH_ART, artifact, dryrun_cell


def show(rec, base=None):
    from repro.analysis.roofline import terms_from_artifact

    t = terms_from_artifact(rec)
    rs = rec.get("rs_wire_bytes_per_dev")
    line = (
        f"{rec['arch']} {rec['shape']} [{rec.get('tag') or 'baseline'} / "
        f"{rec['strategy']}]\n"
        f"  compute={t.compute_s:.4f}s memory={t.memory_s:.4f}s "
        f"collective={t.collective_s:.4f}s dominant={t.dominant}\n"
        f"  MFU@roofline={t.mfu:.4f} model/HLO={t.model_flops_ratio:.3f} "
        f"peak={rec['memory']['peak_est_bytes']/1e9:.1f}GB"
    )
    if rs is not None:
        line += f" rs_adj_collective={rs/50e9:.4f}s"
    if base is not None:
        tb = terms_from_artifact(base)
        line += (
            f"\n  vs baseline: compute x{tb.compute_s/max(t.compute_s,1e-12):.2f} "
            f"memory x{tb.memory_s/max(t.memory_s,1e-12):.2f} "
            f"collective x{tb.collective_s/max(t.collective_s,1e-12):.2f} "
            f"MFU {tb.mfu:.4f} -> {t.mfu:.4f}"
        )
    print(line)
    return t


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("arch")
    ap.add_argument("shape")
    ap.add_argument("--tag", required=True)
    ap.add_argument("--strategy", default=None)
    ap.add_argument("--overrides", default="{}")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    overrides = json.loads(args.overrides)
    rec = dryrun_cell(
        args.arch, args.shape, strategy=args.strategy,
        overrides=overrides or None, tag=args.tag, force=args.force,
        out_dir=os.path.join(os.path.dirname(BENCH_ART), "perf"),
    )
    base = artifact(args.arch, args.shape)
    show(rec, base)


if __name__ == "__main__":
    main()
