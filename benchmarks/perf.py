"""§Perf hillclimb driver: compile tagged variants of the three chosen cells
and print the roofline-term deltas.

    PYTHONPATH=src python -m benchmarks.perf qwen1.5-0.5b train_4k \
        --tag fsdp --strategy fsdp_1d --overrides '{"xent_chunk": 512}'

Also hosts the plan-build micro-timer (:func:`time_plan_builds`): per smoke
program, best-of-N wall time of ``compile_plan`` with the optimizer pipeline
off vs on, so the pass pipeline's compile-time cost (inline / hoist / CSE /
fusion / overlap scheduling) stays visible in ``BENCH_plan.json`` —
recorded, never guarded.  Standalone:

    PYTHONPATH=src python -m benchmarks.perf --plan-build
"""
from __future__ import annotations

import argparse
import json
import os
import time

from .common import BENCH_ART, artifact, dryrun_cell


def time_plan_builds(mesh, programs, repeats: int = 3):
    """Best-of-``repeats`` ``compile_plan`` wall time per program, raw vs
    optimized.  ``programs`` is ``[(name, fn, avals)]`` as produced by
    ``plan_smoke``'s program factories; tracing/propagation happen once
    outside the timed region (the plan build is what the passes tax)."""
    import jax

    from repro.core.plan import compile_plan
    from repro.core.propagation import propagate

    rows = []
    for name, fn, avals in programs:
        closed = jax.make_jaxpr(fn)(*avals)
        prop = propagate(closed, mesh).result()
        # warm once per variant: first build absorbs import/cache warmup
        compile_plan(closed, prop, mesh, optimize=False)
        compile_plan(closed, prop, mesh, optimize=True)

        def best(optimize):
            b = float("inf")
            for _ in range(repeats):
                t0 = time.perf_counter()
                compile_plan(closed, prop, mesh, optimize=optimize)
                b = min(b, (time.perf_counter() - t0) * 1e3)
            return b

        raw_ms, opt_ms = best(False), best(True)
        rows.append({
            "name": name,
            "build_raw_ms": raw_ms,
            "build_opt_ms": opt_ms,
            "pass_overhead_ms": max(opt_ms - raw_ms, 0.0),
        })
    return rows


def plan_build_report():
    """Plan-build timings over the smoke benchmark programs (opt + inline)."""
    from .plan_smoke import _inline_programs, _opt_programs

    mesh, opt_programs = _opt_programs()
    _, inline_programs = _inline_programs()
    return time_plan_builds(mesh, opt_programs + inline_programs)


def pipeline_perf_report(repeats: int = 2):
    """Micro-timings of the §3.3 pipeline path per bench cell: tracing the
    stage-stacked registry loss and one cost-only lowering of it.  Recorded
    into ``BENCH_plan.json["pipeline_build_ms"]`` — never guarded (wall time
    is machine-dependent; the modeled numbers in ``pipeline_cells`` are the
    guarded surface)."""
    from repro import autoshard
    from repro.core.plan import lower_for_cost
    from repro.core.sharding import Mesh
    from repro.pipeline.schedule import PipelineDecision

    from .plan_smoke import _PIPELINE_CASES

    mesh = Mesh.create((2, 4), ("data", "model"))
    rows = []
    for name, arch, rk, batch, seq, _budget, stage_axes, mb in _PIPELINE_CASES:
        ax = (stage_axes or ("model",))[0]
        dec = PipelineDecision(ax, mesh.axis_size(ax), mb or 2)

        def trace():
            return autoshard.registry_pipeline_problem(
                arch, mesh, dec, batch, seq, rk)

        closed, baseline, _ = trace()

        def best(fn):
            b = float("inf")
            for _ in range(repeats):
                t0 = time.perf_counter()
                fn()
                b = min(b, (time.perf_counter() - t0) * 1e3)
            return b

        rows.append({
            "name": name,
            "decision": dec.as_dict(),
            "trace_ms": best(trace),
            "cost_lower_ms": best(
                lambda: lower_for_cost(closed, baseline, mesh)),
        })
    return rows


def show(rec, base=None):
    from repro.analysis.roofline import terms_from_artifact

    t = terms_from_artifact(rec)
    rs = rec.get("rs_wire_bytes_per_dev")
    line = (
        f"{rec['arch']} {rec['shape']} [{rec.get('tag') or 'baseline'} / "
        f"{rec['strategy']}]\n"
        f"  compute={t.compute_s:.4f}s memory={t.memory_s:.4f}s "
        f"collective={t.collective_s:.4f}s dominant={t.dominant}\n"
        f"  MFU@roofline={t.mfu:.4f} model/HLO={t.model_flops_ratio:.3f} "
        f"peak={rec['memory']['peak_est_bytes']/1e9:.1f}GB"
    )
    if rs is not None:
        line += f" rs_adj_collective={rs/50e9:.4f}s"
    if base is not None:
        tb = terms_from_artifact(base)
        line += (
            f"\n  vs baseline: compute x{tb.compute_s/max(t.compute_s,1e-12):.2f} "
            f"memory x{tb.memory_s/max(t.memory_s,1e-12):.2f} "
            f"collective x{tb.collective_s/max(t.collective_s,1e-12):.2f} "
            f"MFU {tb.mfu:.4f} -> {t.mfu:.4f}"
        )
    print(line)
    return t


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("arch", nargs="?")
    ap.add_argument("shape", nargs="?")
    ap.add_argument("--tag", default=None)
    ap.add_argument("--strategy", default=None)
    ap.add_argument("--overrides", default="{}")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--plan-build", action="store_true",
                    help="print plan-build micro-timings for the smoke "
                         "benchmark programs and exit")
    ap.add_argument("--pipeline", action="store_true",
                    help="print §3.3 pipeline trace/lowering micro-timings "
                         "for the pipeline bench cells and exit")
    args = ap.parse_args()
    if args.plan_build:
        for row in plan_build_report():
            print(f"plan_build/{row['name']}: raw={row['build_raw_ms']:.2f}ms "
                  f"opt={row['build_opt_ms']:.2f}ms "
                  f"passes=+{row['pass_overhead_ms']:.2f}ms")
        return
    if args.pipeline:
        for row in pipeline_perf_report():
            d = row["decision"]
            print(f"pipeline_build/{row['name']} "
                  f"[{d['stage_axis']}xS{d['num_stages']}xM"
                  f"{d['num_microbatches']}]: trace={row['trace_ms']:.1f}ms "
                  f"cost_lower={row['cost_lower_ms']:.1f}ms")
        return
    if args.arch is None or args.shape is None or args.tag is None:
        ap.error("arch, shape and --tag are required unless --plan-build "
                 "or --pipeline")
    overrides = json.loads(args.overrides)
    rec = dryrun_cell(
        args.arch, args.shape, strategy=args.strategy,
        overrides=overrides or None, tag=args.tag, force=args.force,
        out_dir=os.path.join(os.path.dirname(BENCH_ART), "perf"),
    )
    base = artifact(args.arch, args.shape)
    show(rec, base)


if __name__ == "__main__":
    main()
