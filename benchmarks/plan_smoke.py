"""Plan-layer smoke benchmark → ``artifacts/bench/BENCH_plan.json``.

Records, per reshard benchmark cell, the planner's chosen collective sequence
and its modeled wire bytes against the greedy AllGather-first baseline, plus
the plan-cache hit rate of a repeated ``spmd_partition`` call and the
planned-collective counts of a compiled plan.  Future PRs diff this artifact
to track the perf trajectory (run via ``python -m benchmarks.run --smoke`` or
``make bench-smoke``).

Everything here is *pure planning* except the cache cell, which executes a
tiny program on a 1×1 mesh — so the smoke target runs in seconds on a single
CPU device.
"""
from __future__ import annotations

import json
import os

import numpy as np

from .common import BENCH_ART

# benchmark mesh for modeled-byte cells: a pod-like 4×8 (planning is pure, no
# devices needed, so the mesh can be bigger than the host)
_MESH_SHAPE = (4, 8)


def _reshard_cells():
    from repro.core.collective_planner import (
        _candidate_gather_all, _candidate_legacy, plan_reshard, simulate,
    )
    from repro.core.sharding import Mesh, mesh_split

    mesh = Mesh.create(_MESH_SHAPE, ("x", "y"))
    # (name, src, dst, local shape under src) — a dim-move, a slice-before-
    # gather, and a stacked-axes drop, on a 4 MiB fp32 operand
    cases = [
        ("dim_move_a2a",
         mesh_split(2, mesh, ["y", -1]), mesh_split(2, mesh, [-1, "y"]),
         (128, 1024)),
        ("slice_before_gather",
         mesh_split(2, mesh, ["x", -1]), mesh_split(2, mesh, [-1, "y"]),
         (256, 1024)),
        ("stacked_drop_inner_first",
         mesh_split(2, mesh, [("x", "y"), -1]), mesh_split(2, mesh, ["x", -1]),
         (32, 1024)),
    ]
    cells = []
    for name, src, dst, local in cases:
        prog = plan_reshard(src, dst, local, dtype_bytes=4)

        def price(gen):
            steps = gen(src, dst, local)
            return simulate(src, dst, steps, local, 4) if steps is not None else None

        # two reference points, both reported: the AllGather-first expression
        # of the move, and the pre-planner greedy schedule (which already used
        # AllToAll when the moving axis was innermost)
        allgather_bytes = price(_candidate_gather_all)
        legacy_bytes = price(_candidate_legacy)
        cells.append({
            "name": name,
            "src": repr(src),
            "dst": repr(dst),
            "local_shape": list(local),
            "planned": prog.collectives(),
            "strategy": prog.strategy,
            "planned_bytes": prog.cost_bytes,
            "allgather_bytes": allgather_bytes,
            "legacy_bytes": legacy_bytes,
            "ratio_vs_allgather": (
                prog.cost_bytes / allgather_bytes if allgather_bytes else 1.0
            ),
            "ratio_vs_legacy": (
                prog.cost_bytes / legacy_bytes if legacy_bytes else 1.0
            ),
        })
    return cells


def _einsum_cell():
    from repro.core.einsum_rules import compile_einsum
    from repro.core.sharding import Mesh, mesh_split
    from repro.analysis.roofline import collective_wire_bytes

    mesh = Mesh.create(_MESH_SHAPE, ("x", "y"))
    lhs = mesh_split(2, mesh, [-1, "y"])
    rhs = mesh_split(2, mesh, ["y", -1])
    out = mesh_split(2, mesh, ["y", -1])
    plan = compile_einsum("bd,df->bf", lhs, rhs, out, (1024, 128), (128, 1024))
    n = mesh.axis_size("y")
    z_bytes = 1024 * 1024 * 4
    # the pre-planner path also had the psum_scatter optimization, so here the
    # AllReduce(+slice) expression is the only meaningful reference
    ar = collective_wire_bytes("all-reduce", n, z_bytes)
    return {
        "name": "einsum_reduce_scatter",
        "planned": plan.collectives(),
        "planned_bytes": plan.cost_bytes,
        "allgather_bytes": ar,
        "legacy_bytes": plan.cost_bytes,
        "ratio_vs_allgather": plan.cost_bytes / ar,
        "ratio_vs_legacy": 1.0,
    }


def _cache_cell():
    import jax.numpy as jnp

    from repro.core import annotate, mesh_split
    from repro.core.compat import make_jax_mesh
    from repro.core.partitioner import spmd_partition
    from repro.core.sharding import Mesh

    jmesh = make_jax_mesh((1, 1), ("x", "y"))
    mesh = Mesh.create((1, 1), ("x", "y"))

    def f(a, b):
        a = annotate(a, mesh_split(2, mesh, ["x", -1]))
        b = annotate(b, mesh_split(2, mesh, [-1, "y"]))
        return jnp.tanh(a @ b)

    runner = spmd_partition(f, jmesh, mesh)
    x = np.ones((8, 8), np.float32)
    for _ in range(5):
        runner(x, x)
    (entry,) = runner.plans.values()
    return {
        "plan_cache": runner.cache_stats.as_dict(),
        "plan_stats": entry.plan.stats.as_dict(),
    }


def smoke_record() -> dict:
    rec = {
        "cells": _reshard_cells() + [_einsum_cell()],
    }
    rec.update(_cache_cell())
    return rec


def write_artifact(rec: dict = None, out_dir: str = None) -> str:
    rec = rec if rec is not None else smoke_record()
    out_dir = out_dir or BENCH_ART
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "BENCH_plan.json")
    json.dump(rec, open(path, "w"), indent=1)
    return path


def rows(rec: dict = None):
    """CSV rows for benchmarks.run (pass ``rec`` to avoid recomputing)."""
    rec = rec if rec is not None else smoke_record()
    out = []
    for cell in rec["cells"]:
        out.append((
            f"plan/{cell['name']}", 0.0,
            f"planned={cell['planned_bytes']:.3e}B "
            f"vs_allgather={cell['ratio_vs_allgather']:.3f} "
            f"vs_legacy={cell['ratio_vs_legacy']:.3f}",
        ))
    pc = rec["plan_cache"]
    out.append((
        "plan/cache", 0.0,
        f"hit_rate={pc['hit_rate']:.2f} ({pc['hits']}h/{pc['misses']}m)",
    ))
    return out
