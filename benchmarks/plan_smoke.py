"""Plan-layer smoke benchmark → ``artifacts/bench/BENCH_plan.json``.

Records, per reshard benchmark cell, the planner's chosen collective sequence
and its modeled wire bytes against the greedy AllGather-first baseline and
the PR 1 (search-disabled) planner; per *optimizer* cell, the whole-plan pass
pipeline's pre- vs post-pass modeled wire bytes, collective-launch counts,
fused-bucket counts, and plan-build wall time; per *inline* cell
(whole-program passes), the pre- vs post-pass whole-program wire bytes and
launches (inner pjit/scan bodies priced at trip count), inlined-body /
hoisted-reshard / in-body-reshard counts, and the overlap scheduler's modeled
makespan-to-serial ratio; per *autoshard* cell, the searched annotation-free
assignment's modeled cost vs the hand-annotated Table-1 baseline under a
per-device memory budget (search is deterministic, cost-only — no jit); per
*guard* cell, the numerics-sentinel epilogue's modeled overhead vs the
unguarded lowering (hard-capped at 1% of total_s); per *profile* cell, the
machine-profile calibration loop (planted-constant recovery, tight-timed
fit + re-score on the harness mesh, calibrated qwen re-scoring); plus
static-verifier telemetry (plans verified / violations — must be 0),
lattice-search cap telemetry, the per-runner and process-level plan-cache hit
rates, and (unguarded) plan-build micro-timings from ``benchmarks/perf.py``.  ``benchmarks/guard.py`` diffs a fresh
run of this module against the committed artifact and fails on regression
(run via ``python -m benchmarks.run --smoke`` or ``make bench-smoke``;
``make bench-guard`` for the diff).

Everything here is *pure planning* except the cache cells, which execute a
tiny program on a 1×1 mesh — so the smoke target runs in seconds on a single
CPU device.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from .common import BENCH_ART

# benchmark mesh for modeled-byte cells: a pod-like 4×8 (planning is pure, no
# devices needed, so the mesh can be bigger than the host)
_MESH_SHAPE = (4, 8)


def _reshard_cells():
    from repro.core.collective_planner import (
        _candidate_gather_all, _candidate_legacy, plan_reshard, simulate,
    )
    from repro.core.sharding import Mesh, mesh_split

    mesh = Mesh.create(_MESH_SHAPE, ("x", "y"))
    mesh3 = Mesh.create((2, 2, 4), ("x", "y", "z"))
    # (name, src, dst, local shape under src) — a dim-move, a slice-before-
    # gather, and a stacked-axes drop, on a 4 MiB fp32 operand; plus a 3-axis
    # stacked target where only the lattice search finds the AllToAll detour
    cases = [
        ("dim_move_a2a",
         mesh_split(2, mesh, ["y", -1]), mesh_split(2, mesh, [-1, "y"]),
         (128, 1024)),
        ("slice_before_gather",
         mesh_split(2, mesh, ["x", -1]), mesh_split(2, mesh, [-1, "y"]),
         (256, 1024)),
        ("stacked_drop_inner_first",
         mesh_split(2, mesh, [("x", "y"), -1]), mesh_split(2, mesh, ["x", -1]),
         (32, 1024)),
        ("lattice_3axis_stacked_target",
         mesh_split(2, mesh3, [-1, "x"]), mesh_split(2, mesh3, [-1, ("z", "x")]),
         (1024, 512)),
    ]
    cells = []
    for name, src, dst, local in cases:
        prog = plan_reshard(src, dst, local, dtype_bytes=4)

        def price(gen):
            steps = gen(src, dst, local)
            return simulate(src, dst, steps, local, 4) if steps is not None else None

        # three reference points, all reported: the AllGather-first expression
        # of the move, the pre-planner greedy schedule, and the PR 1 planner
        # (candidate families only, no lattice search)
        allgather_bytes = price(_candidate_gather_all)
        legacy_bytes = price(_candidate_legacy)
        pr1_bytes = plan_reshard(src, dst, local, dtype_bytes=4, search=False).cost_bytes
        cells.append({
            "name": name,
            "src": repr(src),
            "dst": repr(dst),
            "local_shape": list(local),
            "planned": prog.collectives(),
            "strategy": prog.strategy,
            "planned_bytes": prog.cost_bytes,
            "allgather_bytes": allgather_bytes,
            "legacy_bytes": legacy_bytes,
            "pr1_bytes": pr1_bytes,
            "ratio_vs_allgather": (
                prog.cost_bytes / allgather_bytes if allgather_bytes else 1.0
            ),
            "ratio_vs_legacy": (
                prog.cost_bytes / legacy_bytes if legacy_bytes else 1.0
            ),
            "ratio_vs_pr1": (
                prog.cost_bytes / pr1_bytes if pr1_bytes else 1.0
            ),
        })
    return cells


def _einsum_cell():
    from repro.core.einsum_rules import compile_einsum
    from repro.core.sharding import Mesh, mesh_split
    from repro.analysis.roofline import collective_wire_bytes

    mesh = Mesh.create(_MESH_SHAPE, ("x", "y"))
    lhs = mesh_split(2, mesh, [-1, "y"])
    rhs = mesh_split(2, mesh, ["y", -1])
    out = mesh_split(2, mesh, ["y", -1])
    plan = compile_einsum("bd,df->bf", lhs, rhs, out, (1024, 128), (128, 1024))
    n = mesh.axis_size("y")
    z_bytes = 1024 * 1024 * 4
    # the pre-planner path also had the psum_scatter optimization, so here the
    # AllReduce(+slice) expression is the only meaningful reference
    ar = collective_wire_bytes("all-reduce", n, z_bytes)
    return {
        "name": "einsum_reduce_scatter",
        "planned": plan.collectives(),
        "planned_bytes": plan.cost_bytes,
        "allgather_bytes": ar,
        "legacy_bytes": plan.cost_bytes,
        "pr1_bytes": plan.cost_bytes,
        "ratio_vs_allgather": plan.cost_bytes / ar,
        "ratio_vs_legacy": 1.0,
        "ratio_vs_pr1": 1.0,
    }


# ---------------------------------------------------------------------------------
# whole-plan optimizer cells (PR 2): pre- vs post-pass bytes and launches
# ---------------------------------------------------------------------------------


def _opt_programs():
    """The three optimizer benchmark programs: CSE, DCE, CSE+fusion fan-out."""
    import jax
    import jax.numpy as jnp
    from jax import ShapeDtypeStruct as Sds

    from repro.core import annotate, mesh_split
    from repro.core.sharding import Mesh

    mesh = Mesh.create(_MESH_SHAPE, ("x", "y"))
    R = mesh_split(2, mesh, [-1, -1])
    f32 = lambda *s: Sds(s, jnp.float32)  # noqa: E731

    def cse_shared_operand(a, w1, w2):
        # `a` consumed by two einsums, both needing the same dim-move reshard
        a = annotate(a, mesh_split(2, mesh, ["y", -1]))
        w1 = annotate(w1, mesh_split(2, mesh, ["y", -1]))
        w2 = annotate(w2, mesh_split(2, mesh, ["y", -1]))
        return (a @ w1) + (a @ w2)

    def dead_reshard(a):
        # an annotation whose resharded value the program never consumes
        a1 = annotate(a, mesh_split(2, mesh, ["x", -1]))
        _dead = annotate(a1, mesh_split(2, mesh, [-1, "y"]))
        return jnp.tanh(a1)

    def fused_allreduce_fanout(a, w1, w2, w3, w4):
        # shared-operand CSE + four independent psums bucketed into one launch
        a = annotate(a, mesh_split(2, mesh, ["y", -1]))
        outs = []
        for w in (w1, w2, w3, w4):
            w = annotate(w, mesh_split(2, mesh, ["y", -1]))
            outs.append(annotate(a @ w, R))
        return tuple(outs)

    return mesh, [
        ("cse_shared_operand", cse_shared_operand, [f32(512, 512)] * 3),
        ("dead_reshard", dead_reshard, [f32(512, 512)]),
        ("fused_allreduce_fanout", fused_allreduce_fanout, [f32(256, 256)] * 5),
    ]


def _opt_cells():
    import jax

    from repro.core.plan import compile_plan
    from repro.core.propagation import propagate

    mesh, programs = _opt_programs()
    cells = []
    for name, fn, avals in programs:
        closed = jax.make_jaxpr(fn)(*avals)
        prop = propagate(closed, mesh).result()
        # warm both variants once (first build absorbs import/cache warmup,
        # which would otherwise make the raw build look slower than raw+passes),
        # then report best-of-2
        compile_plan(closed, prop, mesh, optimize=False)
        compile_plan(closed, prop, mesh, optimize=True)

        def _time(optimize):
            best = float("inf")
            for _ in range(2):
                t0 = time.perf_counter()
                p = compile_plan(closed, prop, mesh, optimize=optimize)
                best = min(best, (time.perf_counter() - t0) * 1e3)
            return best, p

        build_raw_ms, _ = _time(False)
        build_opt_ms, plan = _time(True)
        rep = plan.opt_report.as_dict()
        cells.append({
            "name": name,
            "wire_bytes_before": rep["wire_bytes_before"],
            "wire_bytes_after": rep["wire_bytes_after"],
            "collectives_before": rep["collectives_before"],
            "collectives_after": rep["collectives_after"],
            "steps_before": rep["steps_before"],
            "steps_after": rep["steps_after"],
            "fused_buckets": rep["fused_buckets"],
            "launch_s_saved": rep["launch_s_saved"],
            "passes": rep["passes"],
            "build_raw_ms": build_raw_ms,
            "build_opt_ms": build_opt_ms,
        })
    return cells


# ---------------------------------------------------------------------------------
# whole-program cells (PR 4): pjit inlining, scan hoisting, overlap scheduling
# ---------------------------------------------------------------------------------


def _inline_programs():
    """Benchmark programs whose wins need the whole-program passes: a shared
    in-body param gather (CSE only fires after pjit inlining), in-body psums
    (fusable only after inlining), a loop-invariant scan gather (hoist), and
    an independent gather behind a compute chain (overlap scheduling)."""
    import jax
    import jax.numpy as jnp
    from jax import ShapeDtypeStruct as Sds
    from jax import lax

    from repro.core import annotate, mesh_split
    from repro.core.sharding import Mesh

    mesh = Mesh.create(_MESH_SHAPE, ("x", "y"))
    R = mesh_split(2, mesh, [-1, -1])
    W = mesh_split(2, mesh, ["y", -1])
    f32 = lambda *s: Sds(s, jnp.float32)  # noqa: E731

    def gather_block(x, w):
        wg = annotate(annotate(w, W), R)  # in-body gather of the param
        return x @ wg

    gather_blk = jax.jit(gather_block)

    def pjit_shared_param_gather(x, w):
        # two pjit bodies each gathering the same param: the duplicate
        # collective is invisible to CSE until inlining dissolves the calls
        return gather_blk(x, w) + gather_blk(jnp.sin(x), w)

    def psum_block(x, w):
        return annotate(x @ w, R)  # contracted over y -> in-body AllReduce

    psum_blk = jax.jit(psum_block)

    def pjit_fused_psums(x, w1, w2):
        x = annotate(x, mesh_split(2, mesh, [-1, "y"]))
        w1 = annotate(w1, W)
        w2 = annotate(w2, W)
        return psum_blk(x, w1), psum_blk(x, w2)

    def scan_hoisted_gather(xs, w, c0):
        w = annotate(w, W)

        def body(c, x):
            wg = annotate(annotate(w, W), R)  # per-iteration param gather
            return jnp.tanh(c + x @ wg), ()

        c, _ = lax.scan(body, c0, xs)
        return c

    def overlap_gather_behind_compute(a, w1, w2, p):
        a = annotate(a, mesh_split(2, mesh, ["x", -1]))
        h = jnp.tanh(a @ w1) @ w2  # collective-free compute chain
        p = annotate(p, W)
        pg = annotate(p, R)  # independent gather, consumed at the end
        return h + pg

    return mesh, [
        ("pjit_shared_param_gather", pjit_shared_param_gather,
         [f32(512, 512)] * 2),
        ("pjit_fused_psums", pjit_fused_psums, [f32(256, 256)] * 3),
        ("scan_hoisted_gather", scan_hoisted_gather,
         [f32(8, 256, 256), f32(256, 256), f32(256, 256)]),
        ("overlap_gather_behind_compute", overlap_gather_behind_compute,
         [f32(512, 512)] * 4),
    ]


def _inner_reshards(plan) -> int:
    """Reshard steps still living inside pjit/scan bodies (recursive)."""
    n = 0
    for s in plan.steps:
        if s.inner is not None:
            n += sum(1 for t in s.inner.steps if t.kind == "reshard")
            n += _inner_reshards(s.inner)
    return n


def _inline_cells():
    import jax

    from repro.core.plan import compile_plan
    from repro.core.plan_opt import (
        whole_collective_launches, whole_wire_bytes,
    )
    from repro.core.propagation import propagate

    mesh, programs = _inline_programs()
    cells = []
    for name, fn, avals in programs:
        closed = jax.make_jaxpr(fn)(*avals)
        prop = propagate(closed, mesh).result()
        raw = compile_plan(closed, prop, mesh, optimize=False)
        opt = compile_plan(closed, prop, mesh, optimize=True)
        rep = opt.opt_report
        cells.append({
            "name": name,
            "whole_wire_bytes_before": whole_wire_bytes(raw),
            "whole_wire_bytes_after": whole_wire_bytes(opt),
            "whole_launches_before": whole_collective_launches(raw),
            "whole_launches_after": whole_collective_launches(opt),
            "inner_reshards_before": _inner_reshards(raw),
            "inner_reshards_after": _inner_reshards(opt),
            "inlined_bodies": rep.inlined_bodies,
            "hoisted_reshards": rep.hoisted_reshards,
            "fused_buckets": rep.fused_buckets,
            "overlap_ratio": rep.overlap_ratio,
            "overlap": dict(rep.overlap) if rep.overlap else None,
        })
    return cells


# ---------------------------------------------------------------------------------
# pipeline cells: §3.3 stage-stacked pipelining searched jointly with tensor
# sharding on two registry configs
# ---------------------------------------------------------------------------------

# (name, arch, reduce_k, batch, seq, budget, stage_axes): small batch
# exhausts the data axis.  Cell 1's budget sits below the best pure-tensor
# peak — the regime where microbatched pipelining is how the step FITS (the
# shifting buffer holds one microbatch per stage row, so its live peak is
# the lower one); its stage axis is pinned to `model`, the classic
# PP-over-model × DP-over-data mix.  Cell 2's budget admits both pure tensor
# and pipelining, and the searched pipeline point beats the searched pure-
# tensor assignment outright on modeled seconds — the acceptance cell for
# "mixed assignment at modeled cost <= best pure tensor".
_PIPELINE_CASES = (
    # (name, arch, reduce_k, batch, seq, budget, stage_axes, microbatches)
    ("pipeline_qwen1_5_0_5b", "qwen1.5-0.5b", 6, 4, 32, 35e6, ("model",), None),
    ("pipeline_phi4_mini_3_8b", "phi4-mini-3.8b", 8, 4, 16, 80e6, None, 2),
)
_PIPELINE_KNOBS = dict(top_n=3, sa_steps=4, beam_width=3, max_candidates=8)


def _pipeline_cells():
    from repro import autoshard
    from repro.autoshard.space import pipeline_decisions
    from repro.core.sharding import Mesh
    from repro.pipeline import PipelineConfig
    from repro.pipeline.schedule import schedule_cost

    mesh = Mesh.create((2, 4), ("data", "model"))

    def fin(x):
        return x if x is not None and np.isfinite(x) else None

    cells = []
    for name, arch, rk, batch, seq, budget, stage_axes, mb in _PIPELINE_CASES:
        pcfg = PipelineConfig(max_stages=4, stage_axes=stage_axes,
                              num_microbatches=mb)
        cfg = autoshard.AutoshardConfig(budget_bytes=budget, **_PIPELINE_KNOBS)
        t0 = time.perf_counter()
        closed, baseline = autoshard.registry_problem(arch, mesh, batch, seq, rk)
        pure = autoshard.solve_problem(closed, mesh, cfg, baseline=baseline)
        from repro.configs.registry import get_config
        from repro.launch.train import reduced_config

        rcfg = reduced_config(get_config(arch), rk)
        decisions = pipeline_decisions(mesh, rcfg.num_layers, batch, pcfg)
        handpicked = None  # first decision = the handpicked reference
        best = None  # cheapest searched pipeline point
        for dec in decisions:
            try:
                cp, bp, state_shape = autoshard.registry_pipeline_problem(
                    arch, mesh, dec, batch, seq, rk)
            except ValueError:
                continue
            r = autoshard.solve_problem(cp, mesh, cfg, baseline=bp)
            ent = (dec, r, cp, state_shape)
            if handpicked is None:
                handpicked = ent
            if r.evaluation.feasible and (
                    best is None or r.evaluation.score < best[1].evaluation.score):
                best = ent
        ms = (time.perf_counter() - t0) * 1e3
        cell = {
            "name": name,
            "arch": arch,
            "mesh": list(mesh.shape),
            "reduce_k": rk,
            "batch": batch,
            "seq": seq,
            "budget_bytes": budget,
            "decisions_searched": len(decisions),
            "pure_feasible": bool(pure.evaluation.feasible),
            "pure_total_s": fin(pure.evaluation.score),
            "pipeline_feasible": bool(
                best is not None and best[1].evaluation.feasible),
            "search_ms": ms,
        }
        if best is not None:
            dec, r, cp, state_shape = best
            sched = schedule_cost(cp, r.assignment, mesh, dec,
                                  state_shape=state_shape)
            hp_score = handpicked[1].evaluation.score
            # the §3.3 decision contract: the searched stage count never
            # loses to the handpicked one (it is a point in the search)
            cell.update({
                "chosen": dec.as_dict(),
                "bubble_fraction": sched.bubble,
                "ppermute_bytes": sched.ppermute_bytes,
                "ppermute_launches": sched.ppermute_launches,
                "microbatch_activation_bytes": sched.microbatch_activation_bytes,
                "pipeline_total_s": fin(r.evaluation.score),
                "pipeline_peak_bytes": fin(r.evaluation.cost.peak_bytes),
                "handpicked": handpicked[0].as_dict(),
                "handpicked_total_s": fin(hp_score),
                "ratio_vs_handpicked": (
                    r.evaluation.score / hp_score
                    if np.isfinite(hp_score) and hp_score else 1.0),
                # <= 1.0 means pipelining matches-or-beats the best pure-
                # tensor point (inf pure = only pipelining fits the budget)
                "ratio_vs_pure_tensor": (
                    r.evaluation.score / pure.evaluation.score
                    if pure.evaluation.feasible and pure.evaluation.score
                    else 0.0),
                "pipeline_chosen": bool(
                    r.evaluation.feasible
                    and r.evaluation.score <= pure.evaluation.score),
                "mixed": bool(any(
                    s is not None and any(
                        a != dec.stage_axis
                        for dm in s.dims_mapping for a in dm)
                    for s in r.assignment)),
            })
        cells.append(cell)
    return cells


# ---------------------------------------------------------------------------------
# autoshard cells: searched-vs-hand-annotated modeled cost per registry config
# ---------------------------------------------------------------------------------

# (arch, per-device memory budget): budgets sit between the hand-annotated
# baseline's live peak and the replicated peak, so full replication is
# infeasible and the search must do real work to fit
_AUTOSHARD_CASES = (
    ("qwen1.5-0.5b", 24e6),
    ("mamba2-130m", 10.5e6),
    ("phi4-mini-3.8b", 36e6),
)


def _autoshard_mlp_problem(mesh):
    """A scan/pjit-free search problem (plain MLP): its plan has no inner
    bodies, so the whole-program passes leave its PlanCost components (wire
    bytes, launches, per-device FLOPs) untouched — this cell's score moves
    *only* with the scoring objective, isolating the max-of-terms swap from
    the inline/hoist accounting changes that reprice the registry cells."""
    import jax
    import jax.numpy as jnp
    from jax import ShapeDtypeStruct as Sds

    from repro.core import mesh_split

    def mlp(a, w1, w2):
        return jnp.tanh(a @ w1) @ w2

    closed = jax.make_jaxpr(mlp)(
        Sds((128, 256), jnp.float32), Sds((256, 512), jnp.float32),
        Sds((512, 128), jnp.float32),
    )
    baseline = [  # hand annotation: data-parallel batch, Megatron-split MLP
        mesh_split(2, mesh, ["data", -1]),
        mesh_split(2, mesh, [-1, "model"]),
        mesh_split(2, mesh, ["model", -1]),
    ]
    return closed, baseline


def _autoshard_solve_cell(name, arch, mesh, budget, solve_fn):
    cfg_kw = dict(top_n=3, sa_steps=6, max_candidates=8)
    t0 = time.perf_counter()
    res = solve_fn(budget, cfg_kw)
    ms = (time.perf_counter() - t0) * 1e3
    cost = res.cost  # None when every candidate failed to lower — the
    # cell must still be written (feasible=False, null metrics: the
    # artifact stays strict JSON) so the guard can fail it instead of
    # this module crashing before the guard runs

    def fin(x):
        return x if x is not None and np.isfinite(x) else None

    return {
        "name": name,
        "arch": arch,
        "mesh": list(mesh.shape),
        "budget_bytes": budget,
        "feasible": bool(res.evaluation.feasible),
        "baseline_feasible": bool(res.baseline.feasible),
        "searched_total_s": fin(res.evaluation.score),
        "baseline_total_s": fin(res.baseline.score),
        "ratio_vs_baseline": res.ratio_vs_baseline,
        "searched_peak_bytes": fin(cost.peak_bytes if cost else None),
        "searched_wire_bytes": fin(cost.wire_bytes if cost else None),
        "searched_launches": cost.launches if cost else -1,
        "evals": res.evals,
        "search_ms": ms,
        "assignment": [
            None if s is None else [list(a) for a in s.dims_mapping]
            for s in res.assignment
        ],
    }


def _autoshard_cells():
    from repro import autoshard
    from repro.core.sharding import Mesh

    mesh = Mesh.create((2, 4), ("data", "model"))
    cells = []
    for arch, budget in _AUTOSHARD_CASES:
        def solve_registry(budget, cfg_kw, arch=arch):
            cfg = autoshard.AutoshardConfig(budget_bytes=budget, **cfg_kw)
            return autoshard.solve(arch, mesh, config=cfg)

        cells.append(_autoshard_solve_cell(
            f"autoshard_{arch.replace('.', '_').replace('-', '_')}",
            arch, mesh, budget, solve_registry,
        ))
    # scan/pjit-free cell: score isolates the objective formula (see
    # _autoshard_mlp_problem); budget sits between the hand-annotated and
    # replicated peaks so the search must do real work, like the golden tests
    closed, baseline = _autoshard_mlp_problem(mesh)
    free = autoshard.Evaluator(closed, mesh)
    repl_peak = free([None] * len(baseline)).cost.peak_bytes
    base_peak = free(baseline).cost.peak_bytes
    mlp_budget = (repl_peak + base_peak) / 2.0

    def solve_mlp(budget, cfg_kw):
        cfg = autoshard.AutoshardConfig(budget_bytes=budget, **cfg_kw)
        return autoshard.solve_problem(closed, mesh, cfg, baseline=baseline,
                                       arch="mlp-scanfree")

    cells.append(_autoshard_solve_cell(
        "autoshard_mlp_scanfree", "mlp-scanfree", mesh, mlp_budget, solve_mlp,
    ))
    return cells


_ELASTIC_ARCH = "qwen1.5-0.5b"


def _elastic_cells():
    """Elastic-recovery pricing (launch/elastic.py), two cells:

    * ``elastic_reshard_qwen_shrink`` — the plan-lowered reshard program for
      a registry-model mesh-shrink restore: parameters saved under the
      Table-1 layout on (2,4), restored onto the surviving (2,2) mesh in the
      DP-degraded layout (the graceful-fallback path), compiled by
      ``core.plan.compile_state_reshard`` and priced on the roofline —
      modeled reshard seconds, wire bytes, launches, and the ratio against
      the gather-all reference.
    * ``elastic_warm_solve_qwen`` — autoshard re-solve on the shrunk mesh,
      warm-started from the prior (2,4) assignment (Automap-style) vs cold:
      the warm solve must stay feasible and take strictly fewer cost
      lowerings; ``search_ms_*`` are informational wall-clock.
    """
    import jax

    from repro import autoshard
    from repro.configs.base import get_strategy
    from repro.configs.registry import default_strategy, get_config
    from repro.core.plan import compile_state_reshard
    from repro.core.sharding import Mesh, project_dims_mapping
    from repro.launch.train import reduced_config
    from repro.models import api as model_api
    from repro.models.layers import tree_shapes, tree_specs
    from repro.train.checkpoint import _flatten_with_paths

    old = Mesh.create((2, 4), ("data", "model"))
    new = Mesh.create((2, 2), ("data", "model"))
    cells = []

    # -- cell 1: mesh-shrink restore as a priced reshard program ------------
    cfg = reduced_config(get_config(_ELASTIC_ARCH), 16).with_(
        attn_chunk=16, remat="none")
    st = get_strategy(default_strategy(_ELASTIC_ARCH))
    tree = model_api.param_tree(cfg, st)
    from jax.sharding import PartitionSpec as P

    fill = lambda t: jax.tree_util.tree_map(
        lambda s: s if s is not None else P(),
        t, is_leaf=lambda x: x is None or isinstance(x, P))
    shapes_flat, _ = _flatten_with_paths(tree_shapes(tree))
    specs_flat, _ = _flatten_with_paths(fill(tree_specs(tree)))
    items = []
    for (key, sds), (_, spec) in zip(shapes_flat, specs_flat):
        dims = tuple(
            ((e,) if isinstance(e, str) else tuple(e or ()))
            for e in list(spec)[:len(sds.shape)])
        src = project_dims_mapping(new, dims, tuple(sds.shape))
        dp = tuple(tuple(a for a in d if a == "data") for d in dims)
        dst = project_dims_mapping(new, dp, tuple(sds.shape))
        items.append((key, src, dst, tuple(sds.shape), str(sds.dtype)))
    plan = compile_state_reshard(items, new)
    rep = plan.report()
    cells.append({
        "name": "elastic_reshard_qwen_shrink",
        "arch": _ELASTIC_ARCH,
        "mesh_from": list(old.shape), "mesh_to": list(new.shape),
        **{k: rep[k] for k in (
            "leaves", "resharded_leaves", "wire_bytes", "launches",
            "gather_all_bytes", "ratio_vs_gather_all", "reshard_s")},
        "collectives": rep["collectives"],
    })

    # -- cell 2: warm vs cold re-solve on the shrunk mesh -------------------
    cfg_s = autoshard.AutoshardConfig(top_n=3, sa_steps=6, max_candidates=8)
    closed_old, base_old = autoshard.registry_problem(_ELASTIC_ARCH, old)
    prior = autoshard.solve_problem(closed_old, old, cfg_s, baseline=base_old,
                                    arch=_ELASTIC_ARCH)
    closed_new, base_new = autoshard.registry_problem(_ELASTIC_ARCH, new)
    inv_shapes = [tuple(v.aval.shape) for v in closed_new.jaxpr.invars]
    warm_init = autoshard.remap_assignment(prior.assignment, new, inv_shapes)
    t0 = time.perf_counter()
    warm = autoshard.solve_problem(closed_new, new, cfg_s, baseline=base_new,
                                   arch=_ELASTIC_ARCH, warm_start=warm_init)
    warm_ms = (time.perf_counter() - t0) * 1e3
    t0 = time.perf_counter()
    cold = autoshard.solve_problem(closed_new, new, cfg_s, baseline=base_new,
                                   arch=_ELASTIC_ARCH)
    cold_ms = (time.perf_counter() - t0) * 1e3

    def fin(x):
        return x if x is not None and np.isfinite(x) else None

    cells.append({
        "name": "elastic_warm_solve_qwen",
        "arch": _ELASTIC_ARCH,
        "mesh_from": list(old.shape), "mesh_to": list(new.shape),
        "warm_feasible": bool(warm.evaluation.feasible),
        "warm_started": bool(warm.warm_started),
        "cold_feasible": bool(cold.evaluation.feasible),
        "evals_warm": warm.evals,
        "evals_cold": cold.evals,
        "search_ms_warm": warm_ms,   # informational, never guarded
        "search_ms_cold": cold_ms,
        "warm_total_s": fin(warm.evaluation.score),
        "cold_total_s": fin(cold.evaluation.score),
        "ratio_warm_vs_cold": (
            warm.evaluation.score / cold.evaluation.score
            if cold.evaluation.feasible and cold.evaluation.score else 1.0),
    })
    return cells


# ---------------------------------------------------------------------------------
# guarded-execution cells (PR 7): the numerics-sentinel epilogue priced on
# the roofline — its modeled overhead must stay under 1% of the step
# ---------------------------------------------------------------------------------

_GUARD_OVERHEAD_CAP = 0.01  # sentinel cost budget: ≤ 1% of modeled total_s


def _guard_cells():
    """Price ``lower_for_cost(..., guard=GuardConfig())`` against the
    unguarded lowering: one registry-model loss program (the train-step
    shape) and one multi-output fan-out (4 guarded outputs — the worst
    per-output case in the optimizer grid).  ``overhead_ratio`` is the
    guarded-minus-plain modeled seconds over the plain total; the guard
    asserts it stays under :data:`_GUARD_OVERHEAD_CAP`."""
    import jax

    from repro import autoshard
    from repro.core.plan import GuardConfig, lower_for_cost
    from repro.core.propagation import propagate
    from repro.core.sharding import Mesh

    cells = []

    def cell(name, plain, guarded, leaves, cap):
        return {
            "name": name,
            "guarded_leaves": leaves,
            "plain_total_s": plain.total_s,
            "guarded_total_s": guarded.total_s,
            "overhead_s": guarded.total_s - plain.total_s,
            "overhead_ratio": (
                (guarded.total_s - plain.total_s) / plain.total_s
                if plain.total_s else 0.0),
            # None = structural cell: the program is a micro-benchmark whose
            # total_s is launch-overhead-dominated, so a relative cap is
            # meaningless — only the epilogue's step/launch/byte counts and
            # the no-regress check are guarded
            "overhead_cap": cap,
            "guard_steps": guarded.steps - plain.steps,
            "guard_launches": guarded.launches - plain.launches,
            "guard_wire_bytes": guarded.wire_bytes - plain.wire_bytes,
        }

    # registry loss program under the Table-1 baseline — a realistically
    # sized step (the modeled total is compute-dominated, like a real train
    # step), so the ≤1% sentinel budget is asserted here
    rmesh = Mesh.create((2, 4), ("data", "model"))
    closed, baseline = autoshard.registry_problem("qwen1.5-0.5b", rmesh, 8, 256, 8)
    plain = lower_for_cost(closed, baseline, rmesh)
    guarded = lower_for_cost(closed, baseline, rmesh, guard=GuardConfig())
    cells.append(cell("guard_overhead_qwen_loss", plain, guarded, 1,
                      _GUARD_OVERHEAD_CAP))

    # multi-output fan-out: every output guarded (4 stat steps + pack + pmax);
    # a micro-program, so structural-only (cap None)
    mesh, programs = _opt_programs()
    name, fn, avals = next(p for p in programs
                           if p[0] == "fused_allreduce_fanout")
    closed = jax.make_jaxpr(fn)(*avals)
    from repro.core.plan import compile_plan, plan_cost

    prop = propagate(closed, mesh).result()
    plain = plan_cost(compile_plan(closed, prop, mesh, cost_only=True))
    guarded = plan_cost(compile_plan(closed, prop, mesh, cost_only=True,
                                     guard=GuardConfig()))
    cells.append(cell("guard_overhead_fanout", plain, guarded, 4, None))
    return cells


# ---------------------------------------------------------------------------------
# observability cells (PR 8): plan-step tracing + calibration — tracing must
# observe, never perturb (off = provably free, on = plan priced identically)
# ---------------------------------------------------------------------------------

_OBS_OVERHEAD_CAP = 0.01  # tracing cost budget, same bar as the sentinel


def _obs_cells():
    """Two cells for the ``repro.obs`` layer.

    ``obs_trace_qwen`` — the qwen registry loss on the Table-1 mesh,
    cost-only: the modeled timeline must replay to exactly the overlap
    scheduler's makespan, the Chrome export must validate against the trace
    schema, and exporting must not reprice the plan (``overhead_ratio``
    compares ``plan_cost`` before/after the export — tracing is observation,
    so the guarded cap is really an identity check).

    ``obs_exec_tiny`` — an executed traced runner on the 1×1 harness mesh:
    measured + modeled lanes present, schema-valid, calibration table
    complete (a ratio for every priced step class).  The tracing-*off* proof
    rides here too: a runner built with ``TraceConfig(enabled=False)`` must
    hit the process plan cache — same entry, same jitted callable as the
    untraced build, so its overhead is zero by construction, not by timing.
    """
    from repro import autoshard, obs
    from repro.core.plan import lower_plan, plan_cost
    from repro.core.plan_opt import modeled_timeline
    from repro.core.sharding import Mesh

    cells = []

    rmesh = Mesh.create((2, 4), ("data", "model"))
    closed, baseline = autoshard.registry_problem("qwen1.5-0.5b", rmesh, 8,
                                                  256, 8)
    plan = lower_plan(closed, baseline, rmesh)
    cost_before = plan_cost(plan).total_s
    t0 = time.perf_counter()
    tracer = obs.Tracer(obs.TraceConfig(measured=False))
    tracer.on_plan(plan)
    trace = tracer.chrome_trace(include_control=False)
    export_ms = (time.perf_counter() - t0) * 1e3
    cost_after = plan_cost(plan).total_s
    rows_m = modeled_timeline(plan)
    makespan = max((r["start_s"] + r["dur_s"] for r in rows_m), default=0.0)
    sched = plan.opt_report.overlap["overlapped_s"]
    problems = obs.validate_trace_events(trace["traceEvents"])
    cells.append({
        "name": "obs_trace_qwen",
        "steps": len(rows_m),
        "classes": sorted({r["cls"] for r in rows_m}),
        "events": len(trace["traceEvents"]),
        "schema_ok": not problems,
        "schema_problems": len(problems),
        "modeled_makespan_s": makespan,
        "schedule_overlapped_s": sched,
        "makespan_matches_schedule": bool(
            abs(makespan - sched) <= 1e-9 * max(abs(sched), 1e-30)),
        "overhead_ratio": (abs(cost_after - cost_before) / cost_before
                           if cost_before else 0.0),
        "overhead_cap": _OBS_OVERHEAD_CAP,
        "export_ms": export_ms,  # informational, never guarded
    })

    import jax.numpy as jnp

    from repro.core import annotate, mesh_split
    from repro.core.compat import make_jax_mesh
    from repro.core.partitioner import (
        clear_process_plan_cache, process_plan_cache_stats, spmd_partition,
    )

    jmesh = make_jax_mesh((1, 1), ("x", "y"))
    mesh = Mesh.create((1, 1), ("x", "y"))

    def make_fn():
        def f(a, b):
            a = annotate(a, mesh_split(2, mesh, ["x", -1]))
            b = annotate(b, mesh_split(2, mesh, [-1, "y"]))
            return jnp.tanh(a @ b)

        return f

    x = np.ones((8, 8), np.float32)
    clear_process_plan_cache()
    base = spmd_partition(make_fn(), jmesh, mesh)
    base(x, x)
    off = spmd_partition(make_fn(), jmesh, mesh,
                         trace=obs.TraceConfig(enabled=False))
    off(x, x)
    off_hit = (process_plan_cache_stats().hits >= 1 and off.tracer is None)

    runner = spmd_partition(make_fn(), jmesh, mesh, trace=obs.TraceConfig())
    t0 = time.perf_counter()
    for _ in range(3):
        runner(x, x)
    exec_ms = (time.perf_counter() - t0) * 1e3
    trace2 = runner.tracer.chrome_trace()
    problems2 = obs.validate_trace_events(trace2["traceEvents"])
    rep = obs.calibration_report(trace2)
    clear_process_plan_cache()
    cells.append({
        "name": "obs_exec_tiny",
        "measured_events": len(runner.tracer.measured_events()),
        "modeled_events": len(runner.tracer.modeled_events()),
        "schema_ok": not problems2,
        "schema_problems": len(problems2),
        "calibration_complete": rep.complete,
        "calibration": rep.as_dict(),  # ratios vary per run: never guarded
        "off_process_cache_hit": off_hit,
        # off-path overhead is structural (cache-hit ⇒ identical callable):
        # 0 when the hit happened, sentinel 1.0 (fails the cap) otherwise
        "overhead_ratio": 0.0 if off_hit else 1.0,
        "overhead_cap": _OBS_OVERHEAD_CAP,
        "exec_ms": exec_ms,  # informational, never guarded
    })
    return cells


def _chaos_cells():
    """Chaos-soak acceptance cell (launch/chaos.py): a seeded three-event
    campaign — mesh shrink at step 3, NaN burst at step 7, regrow at step 11
    (the 1-device lose=0/gain=0 edition: the full recovery machinery runs,
    no extra devices needed) — soaked end-to-end through the elastic
    coordinator with the invariant battery evaluated after the run.

    Guarded (``guard._check_chaos_cell``): zero invariant violations, every
    injected event fired and restored in a single pass, both mesh-changing
    recoveries warm-started with strictly fewer evals than a cold solve on
    the final mesh.  ``recovery_ms_*`` are wall-clock recovery latencies —
    informational, never guarded."""
    import tempfile

    from repro import autoshard
    from repro.launch import chaos
    from repro.launch.elastic import sharding_problem

    spec = chaos.CampaignSpec(seed=7, steps=14, ckpt_every=2, schedule=[
        {"kind": "device_loss", "step": 3, "lose": 0},
        {"kind": "nan_burst", "step": 7, "steps": 1},
        {"kind": "device_return", "step": 11, "gain": 0},
    ])
    report = chaos.run_campaign(spec, tempfile.mkdtemp(prefix="bench_chaos_"))
    warm_evals = [r["evals"] for r in report.recoveries if "evals" in r]
    # cold reference on the final mesh, same solver budget as the campaign
    cfg, st = chaos._default_model()
    from repro.core.sharding import Mesh

    mesh = Mesh.create((1, 1), ("data", "model"))
    closed, baseline = sharding_problem(cfg, st, mesh, 4, 16)
    cold = autoshard.solve_problem(
        closed, mesh,
        autoshard.AutoshardConfig(top_n=2, sa_steps=2, max_candidates=6),
        baseline=baseline)
    rms = report.recovery_ms or {}
    return [{
        "name": "chaos_soak_shrink_nan_regrow",
        "seed": spec.seed, "steps": spec.steps,
        "n_events": len(spec.schedule),
        "ok": report.ok,
        "violations": report.violations,
        "recoveries": len(report.recoveries),
        "restores": sum(1 for r in report.recoveries
                        if "restored_from" in r),
        "single_pass": all(ep["restores"] == 1 for ep in report.narrative),
        "warm_started_all": all(
            r.get("warm_started", True) for r in report.recoveries),
        "evals_warm_max": max(warm_evals) if warm_evals else 0,
        "evals_cold": cold.evals,
        "losses": report.losses,
        "recovery_ms_max": rms.get("max"),    # informational, never guarded
        "recovery_ms_mean": rms.get("mean"),
    }]


# ---------------------------------------------------------------------------------
# machine-profile cells (PR 10): tight-timed spans → fitted roofline constants
# → calibrated re-scoring, guarded end to end
# ---------------------------------------------------------------------------------

# max relative error for the synthetic planted-constant recovery: the system
# is exact and linear, so the fitter must invert it to f32 tolerance
_PROFILE_FIT_TOL = 1e-6


def _profile_cells():
    """Three cells for the calibration loop (``repro.obs.profile``).

    ``profile_fit_synthetic`` — deterministic planted-constant recovery:
    synthetic per-step samples generated *from* a known
    :class:`RooflineParams` must fit back to the planted constants within
    :data:`_PROFILE_FIT_TOL` relative error, with nothing flagged.

    ``profile_loop_tiny`` — the loop end to end on the 1×1 harness mesh: a
    matmul chain executed under ``TraceConfig(timing="tight")``, spans
    joined to ``step_features``, a profile fitted, and the re-score bar
    asserted — every in-band step class's measured/modeled ratio strictly
    closer to 1.0 (log space) under the fitted constants than under the
    defaults.  The profile-*off* proof and cache isolation ride here: two
    default builds share one process-cache entry (bit-identical to the
    pre-profile world), and two builds under *distinct* profiles add two
    distinct entries (calibrated and default plans never collide).  Memory
    telemetry (modeled peak vs allocator stats, ``None`` on CPU) and the
    ``profile_applied`` control events are recorded alongside.  Raw
    timings and fitted constants vary per host — never guarded; the guard
    checks the booleans only.

    ``profile_rescore_qwen`` — calibrated re-scoring of the qwen autoshard
    problem under a fixed deterministic profile: ``total_s`` must *change*
    (the profile actually reprices the objective) while the searched
    assignment still never loses to the hand-annotated baseline
    (``ratio_vs_baseline`` ≤ 1.0).
    """
    import dataclasses

    import jax.numpy as jnp

    from repro import autoshard, obs
    from repro.analysis.roofline import DEFAULT_PARAMS, RooflineParams
    from repro.core import annotate, mesh_split
    from repro.core import partitioner
    from repro.core.compat import make_jax_mesh
    from repro.core.partitioner import (
        clear_process_plan_cache, process_plan_cache_stats, spmd_partition,
    )
    from repro.core.plan import lower_for_cost
    from repro.core.sharding import Mesh
    from repro.obs.profile import (
        StepSample, collect_samples, device_memory_stats, fit_profile,
        memory_report, rescore_report,
    )
    from repro.obs.trace import control_events

    cells = []

    # -- cell 1: planted-constant recovery on synthetic spans ---------------
    planted = RooflineParams(peak_flops=1.5e13, ici_bw=2.5e10,
                             collective_launch_s=2.5e-5)
    feats = [  # (class, flops, wire_bytes, launches) — spans two compute
        ("einsum", 2e9, 0.0, 0.0), ("einsum", 8e9, 0.0, 0.0),
        ("eltwise", 5e8, 0.0, 0.0),  # classes and three collective shapes
        ("reshard", 0.0, 4e6, 1.0), ("reshard", 0.0, 3.2e7, 1.0),
        ("reshard", 0.0, 1e5, 2.0),
    ]
    samples = []
    for cls, fl, wb, la in feats:
        s = StepSample(cls=cls, flops=fl, wire_bytes=wb, launches=la,
                       measured_s=0.0)
        samples.append(dataclasses.replace(
            s, measured_s=s.modeled_s(planted)))
    prof = fit_profile(samples, source="bench:synthetic")
    pd, fd = planted.as_dict(), prof.params.as_dict()
    rel = {k: abs(fd[k] - pd[k]) / pd[k] for k in prof.fitted}
    max_rel = max(rel.values()) if rel else 1.0
    cells.append({
        "name": "profile_fit_synthetic",
        "n_samples": prof.n_samples,
        "dropped": prof.dropped,
        "planted": pd,
        "fitted": fd,
        "fitted_fields": sorted(prof.fitted),
        "max_rel_err": max_rel,
        "recovered": bool(
            set(prof.fitted) == {"peak_flops", "ici_bw",
                                 "collective_launch_s"}
            and max_rel <= _PROFILE_FIT_TOL and not prof.flagged),
        "flagged": list(prof.flagged),
    })

    # -- cell 2: the loop end to end on the 1×1 harness mesh ----------------
    jmesh = make_jax_mesh((1, 1), ("x", "y"))
    mesh = Mesh.create((1, 1), ("x", "y"))

    def make_chain():
        def f(a, b):
            x = annotate(a, mesh_split(2, mesh, ["x", -1]))
            b = annotate(b, mesh_split(2, mesh, [-1, "y"]))
            for _ in range(4):
                x = jnp.tanh(x @ b)
            return x

        return f

    rng = np.random.default_rng(0)
    a = rng.standard_normal((256, 256)).astype(np.float32)
    b = rng.standard_normal((256, 256)).astype(np.float32)

    ev0 = sum(1 for e in control_events() if e["name"] == "profile_applied")
    runner = spmd_partition(make_chain(), jmesh, mesh,
                            trace=obs.TraceConfig(timing="tight", repeats=3))
    mem0 = device_memory_stats()
    runner(a, b)
    mem1 = device_memory_stats()
    entry = next(iter(runner.plans.values()))
    samples = collect_samples(entry.plan, runner.tracer.measured_events())
    prof = fit_profile(samples, source="bench:profile_loop_tiny")
    res = rescore_report(samples, prof.params)
    mem = memory_report(entry.plan, mem0, mem1)

    # profile-off identity: two default call sites share one cache entry
    clear_process_plan_cache()
    spmd_partition(make_chain(), jmesh, mesh)(a, b)
    spmd_partition(make_chain(), jmesh, mesh)(a, b)
    st = process_plan_cache_stats()
    off_hit = bool(st.hits >= 1 and len(partitioner._PROCESS_CACHE) == 1)
    # cache isolation: two *distinct* profiles must add two distinct entries
    p1 = prof.params
    p2 = dataclasses.replace(p1, peak_flops=p1.peak_flops * 2.0)
    spmd_partition(make_chain(), jmesh, mesh, profile=p1)(a, b)
    spmd_partition(make_chain(), jmesh, mesh, profile=p2)(a, b)
    n_entries = len(partitioner._PROCESS_CACHE)
    ev1 = sum(1 for e in control_events() if e["name"] == "profile_applied")
    clear_process_plan_cache()
    cells.append({
        "name": "profile_loop_tiny",
        "n_samples": prof.n_samples,
        "dropped": prof.dropped,
        "fitted_fields": sorted(prof.fitted),
        "params": prof.params.as_dict(),       # host-specific: never guarded
        "defaults": DEFAULT_PARAMS.as_dict(),
        "residuals": dict(prof.residuals),     # host-specific: never guarded
        "flagged": list(prof.flagged),
        "in_band_classes": res["in_band_classes"],
        "improved_all": bool(res["improved_all"]),
        "off_cache_hit": off_hit,
        "isolation_entries": n_entries,
        "isolation_ok": bool(n_entries == 3),
        "profile_applied_events": ev1 - ev0,
        "memory": mem,
    })

    # -- cell 3: calibrated re-scoring of the qwen autoshard problem --------
    # fixed deterministic profile (as if fitted on a slower machine): the
    # bench must not depend on this host's timings
    cal = RooflineParams(peak_flops=DEFAULT_PARAMS.peak_flops / 2.0,
                         ici_bw=DEFAULT_PARAMS.ici_bw / 2.0,
                         collective_launch_s=2e-5)
    rmesh = Mesh.create((2, 4), ("data", "model"))
    arch, budget = _AUTOSHARD_CASES[0]
    closed, baseline = autoshard.registry_problem(arch, rmesh)
    base_default = lower_for_cost(closed, baseline, rmesh)
    base_cal = lower_for_cost(closed, baseline, rmesh, profile=cal)
    cfg = autoshard.AutoshardConfig(budget_bytes=budget, top_n=3, sa_steps=6,
                                    max_candidates=8, profile=cal)
    t0 = time.perf_counter()
    r = autoshard.solve_problem(closed, rmesh, cfg, baseline=baseline,
                                arch=arch)
    ms = (time.perf_counter() - t0) * 1e3

    def fin(x):
        return x if x is not None and np.isfinite(x) else None

    cells.append({
        "name": "profile_rescore_qwen",
        "arch": arch,
        "mesh": list(rmesh.shape),
        "budget_bytes": budget,
        "profile": cal.as_dict(),
        "profile_digest": cal.digest(),
        "default_total_s": base_default.total_s,
        "profiled_total_s": base_cal.total_s,
        "total_s_changed": bool(
            abs(base_cal.total_s - base_default.total_s)
            > 1e-12 * max(base_default.total_s, 1e-30)),
        "feasible": bool(r.evaluation.feasible),
        "searched_total_s": fin(r.evaluation.score),
        "baseline_total_s": fin(r.baseline.score),
        "ratio_vs_baseline": r.ratio_vs_baseline,
        "evals": r.evals,
        "search_ms": ms,  # informational, never guarded
    })
    return cells


def _cache_cell():
    import jax.numpy as jnp

    from repro.core import annotate, mesh_split
    from repro.core.compat import make_jax_mesh
    from repro.core.partitioner import (
        clear_process_plan_cache, process_plan_cache_stats, spmd_partition,
    )
    from repro.core.sharding import Mesh

    jmesh = make_jax_mesh((1, 1), ("x", "y"))
    mesh = Mesh.create((1, 1), ("x", "y"))

    def make_fn():
        def f(a, b):
            a = annotate(a, mesh_split(2, mesh, ["x", -1]))
            b = annotate(b, mesh_split(2, mesh, [-1, "y"]))
            return jnp.tanh(a @ b)

        return f

    clear_process_plan_cache()
    runner = spmd_partition(make_fn(), jmesh, mesh)
    x = np.ones((8, 8), np.float32)
    for _ in range(5):
        runner(x, x)
    (entry,) = runner.plans.values()
    # a second call site partitioning the same function: its build must hit
    # the process-level cache (same jaxpr digest + mesh + avals)
    runner2 = spmd_partition(make_fn(), jmesh, mesh)
    runner2(x, x)
    rec = {
        "plan_cache": runner.cache_stats.as_dict(),
        "process_plan_cache": process_plan_cache_stats().as_dict(),
        "plan_stats": entry.plan.stats.as_dict(),
    }
    clear_process_plan_cache()
    return rec


def smoke_record() -> dict:
    from repro.core.collective_planner import (
        reset_search_telemetry, search_telemetry,
    )

    # lattice telemetry: "no reshard cell hits the search caps" is guarded
    # over the reshard/einsum grid ("cells"); the totals additionally cover
    # the optimizer and autoshard cells, where model-sized lowering runs many
    # searches (depth-cap prunes there are the bound working as designed, so
    # only regressions vs the committed record fail)
    reset_search_telemetry()
    from repro import obs

    obs.registry().reset()  # per-record metrics, like the lattice telemetry
    rec = {
        "cells": _reshard_cells() + [_einsum_cell()],
    }
    grid_telemetry = search_telemetry()
    rec["opt_cells"] = _opt_cells()
    rec["inline_cells"] = _inline_cells()
    rec["autoshard_cells"] = _autoshard_cells()
    rec["pipeline_cells"] = _pipeline_cells()
    rec["elastic_cells"] = _elastic_cells()
    rec["guard_cells"] = _guard_cells()
    rec["obs_cells"] = _obs_cells()
    rec["chaos_cells"] = _chaos_cells()
    rec["profile_cells"] = _profile_cells()
    rec.update(_cache_cell())
    rec["lattice_telemetry"] = {
        "cells": grid_telemetry,
        "total": search_telemetry(),
    }
    # static-verifier telemetry (core/plan_verify.py): every plan lowered
    # above was verified post-compile; violations raise, so a record that
    # reaches this line must report zero — guarded as a hard invariant
    from repro.core.plan_verify import verify_telemetry

    rec["plan_verify"] = verify_telemetry()
    # plan-build micro-timings (benchmarks/perf.py): the pass pipeline's
    # compile-time cost — recorded in the artifact, never guarded
    from .perf import pipeline_perf_report, plan_build_report

    rec["plan_build_ms"] = plan_build_report()
    rec["pipeline_build_ms"] = pipeline_perf_report()
    # unified metrics snapshot: every telemetry surface exercised above —
    # plan caches, lattice counters, verifier telemetry, autoshard timing —
    # readable from this one dict (guard checks the sources are all present)
    rec["metrics"] = obs.snapshot()
    return rec


def write_artifact(rec: dict = None, out_dir: str = None) -> str:
    rec = rec if rec is not None else smoke_record()
    out_dir = out_dir or BENCH_ART
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "BENCH_plan.json")
    json.dump(rec, open(path, "w"), indent=1)
    return path


def rows(rec: dict = None):
    """CSV rows for benchmarks.run (pass ``rec`` to avoid recomputing)."""
    rec = rec if rec is not None else smoke_record()
    out = []
    for cell in rec["cells"]:
        out.append((
            f"plan/{cell['name']}", 0.0,
            f"planned={cell['planned_bytes']:.3e}B "
            f"vs_allgather={cell['ratio_vs_allgather']:.3f} "
            f"vs_legacy={cell['ratio_vs_legacy']:.3f} "
            f"vs_pr1={cell['ratio_vs_pr1']:.3f}",
        ))
    for cell in rec["opt_cells"]:
        out.append((
            f"plan_opt/{cell['name']}", 0.0,
            f"bytes={cell['wire_bytes_before']:.3e}->{cell['wire_bytes_after']:.3e} "
            f"launches={cell['collectives_before']}->{cell['collectives_after']} "
            f"fused={cell['fused_buckets']} "
            f"build={cell['build_opt_ms']:.1f}ms",
        ))
    for cell in rec.get("inline_cells", []):
        out.append((
            f"plan_inline/{cell['name']}", 0.0,
            f"bytes={cell['whole_wire_bytes_before']:.3e}->"
            f"{cell['whole_wire_bytes_after']:.3e} "
            f"launches={cell['whole_launches_before']}->"
            f"{cell['whole_launches_after']} "
            f"inlined={cell['inlined_bodies']} hoisted={cell['hoisted_reshards']} "
            f"inner_reshards={cell['inner_reshards_before']}->"
            f"{cell['inner_reshards_after']} "
            f"overlap={cell['overlap_ratio']:.3f}",
        ))
    for cell in rec.get("autoshard_cells", []):
        out.append((
            f"autoshard/{cell['arch']}", 0.0,
            f"searched={cell['searched_total_s']:.3e}s "
            f"baseline={cell['baseline_total_s']:.3e}s "
            f"ratio={cell['ratio_vs_baseline']:.3f} "
            f"peak={cell['searched_peak_bytes']/1e6:.1f}MB "
            f"evals={cell['evals']} search={cell['search_ms']:.0f}ms",
        ))
    for cell in rec.get("pipeline_cells", []):
        if not cell.get("pipeline_feasible"):
            out.append((f"pipeline/{cell['arch']}", 0.0, "no feasible decision"))
            continue
        dec = cell["chosen"]
        out.append((
            f"pipeline/{cell['arch']}", 0.0,
            f"{dec['stage_axis']}xS{dec['num_stages']}xM{dec['num_microbatches']} "
            f"bubble={cell['bubble_fraction']:.3f} "
            f"ppermute={cell['ppermute_bytes']:.2e}B/{cell['ppermute_launches']} "
            f"pipe={cell['pipeline_total_s']:.3e}s "
            f"pure={cell['pure_total_s'] if cell['pure_total_s'] is not None else 'inf'} "
            f"vs_handpicked={cell['ratio_vs_handpicked']:.3f} "
            f"chosen={cell['pipeline_chosen']} mixed={cell['mixed']}",
        ))
    for cell in rec.get("elastic_cells", []):
        if "reshard_s" in cell:
            out.append((
                f"elastic/{cell['name']}", 0.0,
                f"leaves={cell['resharded_leaves']}/{cell['leaves']} "
                f"wire={cell['wire_bytes']:.3e}B launches={cell['launches']} "
                f"reshard={cell['reshard_s']:.3e}s "
                f"vs_gather_all={cell['ratio_vs_gather_all']:.3f}",
            ))
        else:
            out.append((
                f"elastic/{cell['name']}", 0.0,
                f"evals={cell['evals_warm']}w/{cell['evals_cold']}c "
                f"search={cell['search_ms_warm']:.0f}/"
                f"{cell['search_ms_cold']:.0f}ms "
                f"ratio={cell['ratio_warm_vs_cold']:.3f} "
                f"warm_started={cell['warm_started']}",
            ))
    for cell in rec.get("guard_cells", []):
        cap = cell["overhead_cap"]
        out.append((
            f"guard/{cell['name']}", 0.0,
            f"overhead={cell['overhead_ratio']*100:.4f}% "
            f"(cap {f'{cap*100:.0f}%' if cap is not None else 'none'}) "
            f"steps=+{cell['guard_steps']} launches=+{cell['guard_launches']} "
            f"wire=+{cell['guard_wire_bytes']:.2e}B",
        ))
    for cell in rec.get("obs_cells", []):
        if cell["name"] == "obs_trace_qwen":
            out.append((
                f"obs/{cell['name']}", 0.0,
                f"steps={cell['steps']} classes={len(cell['classes'])} "
                f"schema_ok={cell['schema_ok']} "
                f"makespan={cell['modeled_makespan_s']:.3e}s "
                f"matches_schedule={cell['makespan_matches_schedule']} "
                f"export={cell['export_ms']:.1f}ms",
            ))
        else:
            out.append((
                f"obs/{cell['name']}", 0.0,
                f"measured={cell['measured_events']} "
                f"modeled={cell['modeled_events']} "
                f"schema_ok={cell['schema_ok']} "
                f"calibration_complete={cell['calibration_complete']} "
                f"off_cache_hit={cell['off_process_cache_hit']}",
            ))
    for cell in rec.get("profile_cells", []):
        if cell["name"] == "profile_fit_synthetic":
            out.append((
                f"profile/{cell['name']}", 0.0,
                f"recovered={cell['recovered']} "
                f"max_rel_err={cell['max_rel_err']:.2e} "
                f"fitted={','.join(cell['fitted_fields'])} "
                f"dropped={cell['dropped']}",
            ))
        elif cell["name"] == "profile_loop_tiny":
            out.append((
                f"profile/{cell['name']}", 0.0,
                f"samples={cell['n_samples']} "
                f"improved_all={cell['improved_all']} "
                f"in_band={cell['in_band_classes']} "
                f"off_cache_hit={cell['off_cache_hit']} "
                f"isolation_ok={cell['isolation_ok']}",
            ))
        else:
            out.append((
                f"profile/{cell['name']}", 0.0,
                f"total_s={cell['default_total_s']:.3e}->"
                f"{cell['profiled_total_s']:.3e} "
                f"changed={cell['total_s_changed']} "
                f"ratio={cell['ratio_vs_baseline']:.3f} "
                f"search={cell['search_ms']:.0f}ms",
            ))
    mx = rec.get("metrics")
    if mx:
        out.append((
            "obs/metrics_snapshot", 0.0,
            f"counters={len(mx['counters'])} "
            f"histograms={len(mx['histograms'])} "
            f"sources={','.join(sorted(mx.get('sources', {})))}",
        ))
    pv = rec.get("plan_verify")
    if pv:
        out.append((
            "plan/verify_telemetry", 0.0,
            f"plans_verified={pv['plans_verified']} "
            f"violations={pv['violations']}",
        ))
    lt = rec.get("lattice_telemetry", {})
    if lt:
        c, t = lt["cells"], lt["total"]
        out.append((
            "plan/lattice_telemetry", 0.0,
            f"grid: searches={c['searches']} node_cap={c['node_cap_hits']} "
            f"depth_cap={c['depth_cap_hits']} | total: "
            f"searches={t['searches']} node_cap={t['node_cap_hits']} "
            f"depth_cap={t['depth_cap_hits']}",
        ))
    pc = rec["plan_cache"]
    out.append((
        "plan/cache", 0.0,
        f"hit_rate={pc['hit_rate']:.2f} ({pc['hits']}h/{pc['misses']}m)",
    ))
    pp = rec["process_plan_cache"]
    out.append((
        "plan/process_cache", 0.0,
        f"hit_rate={pp['hit_rate']:.2f} ({pp['hits']}h/{pp['misses']}m)",
    ))
    return out
